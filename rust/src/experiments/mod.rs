//! Experiment reproductions — one function per paper table/figure,
//! shared by the CLI (`ita <experiment>`) and the bench targets so the
//! numbers in EXPERIMENTS.md come from exactly one implementation.
//!
//! | function | paper artifact |
//! |---|---|
//! | [`table1`] | Table I (SOTA comparison) |
//! | [`fig5`] | Fig. 5 (softmax/quantization effect on probabilities) |
//! | [`fig6_area`], [`fig6_power`] | Fig. 6 (area & power breakdown) |
//! | [`softmax_mae`] | §V-C (MAE vs I-BERT / float) |
//! | [`mempool_cmp`] | §V-D (6× speedup, 45× energy efficiency) |
//! | [`ablation_dataflow`] | §III bandwidth equations (WS vs OS) |
//! | [`ablation_scale`] | design-space sweep over N/M (extension) |
//! | [`ablation_dividers`] | DI no-stall claim check (extension) |

use crate::baselines::float_softmax::softmax_f64;
use crate::baselines::ibert::ibert_softmax_q_wide;
use crate::baselines::mempool::{self, MemPoolConfig};
use crate::baselines::softermax::softermax_i8;
use crate::ita::area::{system_area_mm2, AreaBreakdown};
use crate::ita::energy::{tops_per_watt, EnergyBreakdown};
use crate::ita::simulator::{AttentionShape, Simulator};
use crate::ita::softmax::{dequantize_probs, epsilon_max, ita_softmax_row};
use crate::ita::ItaConfig;
use crate::util::rng::SplitMix64;
use crate::util::stats::{mae, max_abs_err, mean, rmse};
use crate::util::table::Table;

/// The workload used as "the synthetic attention benchmark" whenever a
/// paper experiment needs one: large enough that every phase is
/// tile-aligned at the paper design point.
pub fn benchmark_shape() -> AttentionShape {
    AttentionShape { s: 256, e: 256, p: 64, h: 4 }
}

// ---------------------------------------------------------------------
// Table I
// ---------------------------------------------------------------------

/// Literature rows of Table I (reported values, for comparison shape).
pub struct SotaRow {
    pub name: &'static str,
    pub tech_nm: u32,
    pub area_mm2: f64,
    pub power_mw: f64,
    pub tops: f64,
    pub tops_w: f64,
    pub tops_mm2: f64,
}

/// Reported numbers from Table I of the paper (not simulated — these
/// are the published comparison points).
pub fn sota_rows() -> Vec<SotaRow> {
    vec![
        SotaRow { name: "OPTIMUS [14]", tech_nm: 28, area_mm2: 5.2, power_mw: 731.8, tops: 0.5, tops_w: 0.68, tops_mm2: 0.096 },
        SotaRow { name: "SpAtten [15]", tech_nm: 40, area_mm2: 18.71, power_mw: 2600.0, tops: 1.61, tops_w: 0.62, tops_mm2: 0.086 },
        SotaRow { name: "ELSA [16]", tech_nm: 40, area_mm2: 1.26, power_mw: 969.4, tops: 1.09, tops_w: 1.12, tops_mm2: 0.865 },
        SotaRow { name: "Wang et al. [12]", tech_nm: 28, area_mm2: 6.82, power_mw: 272.8, tops: 4.07, tops_w: 27.56, tops_mm2: 0.597 },
        SotaRow { name: "Keller [13] INT8", tech_nm: 5, area_mm2: 0.153, power_mw: 0.0, tops: 1.8, tops_w: 39.1, tops_mm2: 11.7 },
    ]
}

/// Simulated "This work" columns + published rows.
pub fn table1(cfg: &ItaConfig) -> Table {
    let shape = benchmark_shape();
    let rep = Simulator::new(*cfg).simulate_attention(shape);
    let a = &rep.activity;
    let area = AreaBreakdown::for_config(cfg);
    let e_core = EnergyBreakdown::for_activity(cfg, a);
    let e_sys = EnergyBreakdown::for_activity_system(cfg, a);
    let cycles = rep.total_cycles();
    let power_core = e_core.avg_power_w(cycles, cfg.freq_hz);
    let power_sys = e_sys.avg_power_w(cycles, cfg.freq_hz);
    let tops = rep.achieved_ops() / 1e12;
    let area_core = area.total_mm2();
    let area_sys = system_area_mm2(cfg, 64 * 1024);
    let ge_m = area.total_ge() / 1e6;

    let mut t = Table::new("Table I — comparison to state-of-the-art (This work: simulated)")
        .header(&["Design", "Tech [nm]", "Area [mm2]", "Power [mW]", "Thru [TOPS]", "Eff [TOPS/W]", "Area-eff [TOPS/mm2]", "TOPS/MGE"]);
    for r in sota_rows() {
        t.row(&[
            r.name.into(),
            r.tech_nm.to_string(),
            format!("{:.3}", r.area_mm2),
            if r.power_mw > 0.0 { format!("{:.1}", r.power_mw) } else { "-".into() },
            format!("{:.2}", r.tops),
            format!("{:.2}", r.tops_w),
            format!("{:.3}", r.tops_mm2),
            "-".into(),
        ]);
    }
    t.row(&[
        "ITA (this repro)".into(),
        "22".into(),
        format!("{area_core:.3}"),
        format!("{:.1}", power_core * 1e3),
        format!("{tops:.2}"),
        format!("{:.1}", tops_per_watt(cfg, a, false)),
        format!("{:.2}", tops / area_core),
        format!("{:.2}", tops / ge_m),
    ]);
    t.row(&[
        "ITA System (this repro)".into(),
        "22".into(),
        format!("{area_sys:.3}"),
        format!("{:.1}", power_sys * 1e3),
        format!("{tops:.2}"),
        format!("{:.2}", tops_per_watt(cfg, a, true)),
        format!("{:.2}", tops / area_sys),
        "-".into(),
    ]);
    t
}

// ---------------------------------------------------------------------
// Fig. 5
// ---------------------------------------------------------------------

/// Fig. 5: effect of softmax and quantization on attention
/// probabilities. For one realistic logit row, prints the sorted
/// probability profile under (a) float softmax, (b) ITA integer
/// softmax at ε_max, and the quantized-to-zero boundary the paper's
/// clipping argument predicts.
pub fn fig5(seed: u64, n: usize) -> Table {
    let mut rng = SplitMix64::new(seed);
    // Compact-transformer-like logits: zero-mean Gaussian scaled so
    // p99.9 fills the clipped window (the QAT-tuned regime).
    let logits: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
    let gain = crate::quant::calib::softmax_logit_gain(&logits);
    let eps = epsilon_max();
    let xf: Vec<f64> = logits.iter().map(|v| v * gain).collect();
    let xq: Vec<i8> = xf.iter().map(|&v| crate::quant::QuantParams { eps }.quantize(v)).collect();

    let pf = softmax_f64(&xf);
    let pq = dequantize_probs(&ita_softmax_row(&xq, 64));

    // Sort by float probability (descending) to show the profile.
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| pf[b].partial_cmp(&pf[a]).unwrap());

    let mut t = Table::new("Fig. 5 — attention probabilities: float vs ITA 8-bit softmax")
        .header(&["rank", "logit (dequant)", "float softmax", "ITA softmax", "abs err"]);
    for (rank, &i) in idx.iter().enumerate() {
        if rank < 16 || rank % (n / 16).max(1) == 0 {
            t.row(&[
                rank.to_string(),
                format!("{:+.3}", xq[i] as f64 * eps),
                format!("{:.5}", pf[i]),
                format!("{:.5}", pq[i]),
                format!("{:.5}", (pf[i] - pq[i]).abs()),
            ]);
        }
    }
    t
}

// ---------------------------------------------------------------------
// Fig. 6
// ---------------------------------------------------------------------

/// Fig. 6 left: area breakdown.
pub fn fig6_area(cfg: &ItaConfig) -> Table {
    let a = AreaBreakdown::for_config(cfg);
    let mut t = Table::new(format!(
        "Fig. 6 — area breakdown (total {:.3} mm2, {:.0} kGE; paper: 0.173 mm2)",
        a.total_mm2(),
        a.total_ge() / 1e3
    )
    .as_str())
    .header(&["Component", "kGE", "share", "paper share"]);
    let paper = [
        ("PEs", 0.581),
        ("Weight buffer", 0.196),
        ("Softmax", 0.033),
        ("Datapath other", 0.063),
        ("Control", 0.023),
        ("Output buffer", 0.011),
        ("I/O registers", 0.093),
    ];
    for ((name, ge, frac), (pname, pshare)) in a.rows().into_iter().zip(paper) {
        assert_eq!(name, pname);
        t.row(&[
            name.into(),
            format!("{:.1}", ge / 1e3),
            format!("{:.1}%", frac * 100.0),
            format!("{:.1}%", pshare * 100.0),
        ]);
    }
    t
}

/// Fig. 6 right: power breakdown over the benchmark workload.
pub fn fig6_power(cfg: &ItaConfig) -> Table {
    let rep = Simulator::new(*cfg).simulate_attention(benchmark_shape());
    let e = EnergyBreakdown::for_activity(cfg, &rep.activity);
    let p = e.avg_power_w(rep.total_cycles(), cfg.freq_hz);
    let mut t = Table::new(format!(
        "Fig. 6 — power breakdown (total {:.1} mW; paper: 60.5 mW)",
        p * 1e3
    )
    .as_str())
    .header(&["Component", "mW", "share", "paper share"]);
    let paper = [
        ("PEs", 0.595),
        ("Clock tree + I/O regs", 0.229),
        ("Datapath other", 0.067),
        ("Weight buffer", 0.017),
        ("Softmax", 0.014),
        ("Output buffer", 0.007),
        ("Static/other", 0.071),
    ];
    let time = rep.total_cycles() as f64 / cfg.freq_hz;
    for ((name, joules, frac), (pname, pshare)) in e.rows().into_iter().zip(paper) {
        assert_eq!(name, pname);
        t.row(&[
            name.into(),
            format!("{:.2}", joules / time * 1e3),
            format!("{:.1}%", frac * 100.0),
            format!("{:.1}%", pshare * 100.0),
        ]);
    }
    t
}

// ---------------------------------------------------------------------
// §V-C softmax accuracy
// ---------------------------------------------------------------------

/// Accuracy statistics of one softmax implementation.
#[derive(Debug, Clone)]
pub struct MaeResult {
    pub name: &'static str,
    pub mae: f64,
    pub rmse: f64,
    pub max_err: f64,
}

/// §V-C: MAE of ITA's softmax vs I-BERT's vs Softermax against float,
/// on realistic logit rows. Returns the stats (also used by pytest via
/// the mirrored Python implementation).
pub fn softmax_mae(seed: u64, rows: usize, row_len: usize) -> Vec<MaeResult> {
    let mut rng = SplitMix64::new(seed);
    let eps = epsilon_max();
    let mut accum: Vec<(&'static str, Vec<f64>, Vec<f64>, Vec<f64>)> = vec![
        ("ITA int8 softmax", vec![], vec![], vec![]),
        ("I-BERT int32 softmax", vec![], vec![], vec![]),
        ("Softermax (base-2 fx)", vec![], vec![], vec![]),
    ];
    for _ in 0..rows {
        // Compact-transformer-like logits, QAT-scaled into the window.
        let raw: Vec<f64> = (0..row_len).map(|_| rng.next_gaussian()).collect();
        let gain = 2.75 / 3.29; // p99.9 of N(0,1) → window edge
        let xf: Vec<f64> = raw.iter().map(|v| v * gain).collect();
        let xq: Vec<i8> =
            xf.iter().map(|&v| crate::quant::QuantParams { eps }.quantize(v)).collect();
        let want = softmax_f64(&xf);

        // ITA: 8-bit input, shift-only datapath.
        let ita = dequantize_probs(&ita_softmax_row(&xq, 64));
        // I-BERT: 16-bit-quantized input (the paper's "32-bit" refers
        // to the arithmetic; the input precision advantage is what the
        // paper credits for its lower MAE).
        let eps16 = 2.75 / 32767.0;
        let xq16: Vec<i64> = xf
            .iter()
            .map(|&v| ((v / eps16).round() as i64).clamp(-32768, 32767))
            .collect();
        // Output re-quantized to uint8 probabilities like ITA's (any
        // integer accelerator stores A in int8; the paper's 0.35 % is
        // consistent with this, not with full 2^-30 outputs).
        let ibert: Vec<f64> = ibert_softmax_q_wide(&xq16, eps16)
            .iter()
            .map(|&q| ((q >> 22).clamp(0, 255)) as f64 / 256.0)
            .collect();
        // Softermax on the same 8-bit input as ITA.
        let sm: Vec<f64> = softermax_i8(&xq, eps).iter().map(|&p| p as f64 / 256.0).collect();

        for (slot, got) in [&ita, &ibert, &sm].iter().enumerate() {
            accum[slot].1.push(mae(&want, got));
            accum[slot].2.push(rmse(&want, got));
            accum[slot].3.push(max_abs_err(&want, got));
        }
    }
    accum
        .into_iter()
        .map(|(name, maes, rmses, maxes)| MaeResult {
            name,
            mae: mean(&maes),
            rmse: mean(&rmses),
            max_err: maxes.iter().cloned().fold(0.0, f64::max),
        })
        .collect()
}

/// Render [`softmax_mae`] as the §V-C table.
pub fn softmax_mae_table(seed: u64, rows: usize, row_len: usize) -> Table {
    let results = softmax_mae(seed, rows, row_len);
    let mut t = Table::new(
        "§V-C — softmax accuracy vs float (paper: ITA 0.46%, I-BERT 0.35%)",
    )
    .header(&["Implementation", "MAE", "MAE %", "RMSE", "max |err|"]);
    for r in results {
        t.row(&[
            r.name.into(),
            format!("{:.2e}", r.mae),
            format!("{:.2}%", r.mae * 100.0),
            format!("{:.2e}", r.rmse),
            format!("{:.3}", r.max_err),
        ]);
    }
    t
}

// ---------------------------------------------------------------------
// §V-D MemPool comparison
// ---------------------------------------------------------------------

/// §V-D: ITA vs the MemPool software baseline across sequence lengths.
pub fn mempool_cmp(cfg: &ItaConfig) -> Table {
    let mp = MemPoolConfig::paper();
    let mut t = Table::new(
        "§V-D — ITA vs MemPool software baseline (paper: 6x speedup, 45x energy eff.)",
    )
    .header(&["S", "ITA cycles", "MemPool cycles", "speedup", "energy ratio"]);
    for s in [64usize, 128, 256, 512] {
        let shape = AttentionShape { s, e: 256, p: 64, h: 4 };
        let (speedup, eff) = mempool::compare(cfg, &mp, shape);
        let ita = Simulator::new(*cfg).simulate_attention(shape);
        let mpr = mempool::simulate_attention(&mp, shape);
        t.row(&[
            s.to_string(),
            ita.total_cycles().to_string(),
            format!("{:.0}", mpr.total_cycles()),
            format!("{speedup:.2}x"),
            format!("{eff:.1}x"),
        ]);
    }
    t
}

// ---------------------------------------------------------------------
// Ablations
// ---------------------------------------------------------------------

/// §III bandwidth equations: weight-stationary vs output-stationary
/// bandwidth requirement across N (the paper's argument for WS).
pub fn ablation_dataflow() -> Table {
    let mut t = Table::new("§III — dataflow bandwidth: weight-stationary vs output-stationary")
        .header(&["N", "M", "WS [bits/cy]", "OS [bits/cy]", "OS/WS", "WS buffer [B]", "OS buffer [B]"]);
    for (n, m) in [(4usize, 64usize), (8, 64), (16, 64), (32, 64), (16, 32), (16, 128)] {
        let mut cfg = ItaConfig::paper();
        cfg.n = n;
        cfg.m = m;
        let ws = cfg.bw_weight_stationary_bits();
        let os = cfg.bw_output_stationary_bits();
        t.row(&[
            n.to_string(),
            m.to_string(),
            ws.to_string(),
            os.to_string(),
            format!("{:.2}x", os as f64 / ws as f64),
            cfg.weight_buffer_bytes().to_string(),
            (2 * m).to_string(),
        ]);
    }
    t
}

/// Design-space sweep over (N, M): area, power, efficiency at the
/// benchmark workload — how the silicon would respond to scaling.
pub fn ablation_scale() -> Table {
    let mut t = Table::new("Design-space sweep (benchmark workload)").header(&[
        "N", "M", "MACs", "Area [mm2]", "Power [mW]", "TOPS", "TOPS/W", "TOPS/mm2", "util",
    ]);
    for (n, m) in [(8usize, 64usize), (16, 32), (16, 64), (16, 128), (32, 64), (64, 64)] {
        let mut cfg = ItaConfig::paper();
        cfg.n = n;
        cfg.m = m;
        cfg.weight_bw = n as u64;
        cfg.input_bw = m as u64;
        cfg.output_bw = n as u64;
        let rep = Simulator::new(cfg).simulate_attention(benchmark_shape());
        let e = EnergyBreakdown::for_activity(&cfg, &rep.activity);
        let area = AreaBreakdown::for_config(&cfg);
        let tops = rep.achieved_ops() / 1e12;
        t.row(&[
            n.to_string(),
            m.to_string(),
            (n * m).to_string(),
            format!("{:.3}", area.total_mm2()),
            format!("{:.1}", e.avg_power_w(rep.total_cycles(), cfg.freq_hz) * 1e3),
            format!("{tops:.2}"),
            format!("{:.1}", tops_per_watt(&cfg, &rep.activity, false)),
            format!("{:.2}", tops / area.total_mm2()),
            format!("{:.2}", rep.utilization()),
        ]);
    }
    t
}

/// DI overlap check: serial-divider count vs softmax-induced stalls
/// (the paper claims two dividers suffice; the model *tests* it).
pub fn ablation_dividers(cfg: &ItaConfig) -> Table {
    let mut t = Table::new("DI overlap check — dividers vs stalls (paper claims 2 suffice)")
        .header(&["dividers", "DI stalls [cy]", "total cycles", "overhead"]);
    for nd in [1usize, 2, 4, 8, 16, 32] {
        let mut c = *cfg;
        c.n_dividers = nd;
        let rep = Simulator::new(c).simulate_attention(benchmark_shape());
        let total = rep.total_cycles();
        t.row(&[
            nd.to_string(),
            rep.di_stall_cycles.to_string(),
            total.to_string(),
            format!("{:.2}%", 100.0 * rep.di_stall_cycles as f64 / total as f64),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_both_this_work_rows() {
        let t = table1(&ItaConfig::paper());
        let s = t.render();
        assert!(s.contains("ITA (this repro)"));
        assert!(s.contains("ITA System"));
        assert!(s.contains("OPTIMUS"));
    }

    #[test]
    fn mae_reproduces_paper_band() {
        let r = softmax_mae(42, 200, 64);
        let ita = &r[0];
        let ibert = &r[1];
        // Paper: ITA 0.46 % — accept [0.2 %, 0.9 %] (distribution-
        // dependent), and I-BERT strictly more accurate than ITA.
        assert!(ita.mae > 0.002 && ita.mae < 0.009, "ITA MAE {}", ita.mae);
        assert!(ibert.mae < ita.mae, "I-BERT {} !< ITA {}", ibert.mae, ita.mae);
    }

    #[test]
    fn fig6_tables_render() {
        let cfg = ItaConfig::paper();
        assert!(fig6_area(&cfg).render().contains("Softmax"));
        assert!(fig6_power(&cfg).render().contains("Clock tree"));
    }

    #[test]
    fn fig5_shows_clipping_profile() {
        let t = fig5(1, 128);
        assert!(t.n_rows() > 10);
    }

    #[test]
    fn mempool_table_rows() {
        let t = mempool_cmp(&ItaConfig::paper());
        assert_eq!(t.n_rows(), 4);
        assert!(t.render().contains("speedup"));
    }

    #[test]
    fn ablations_render() {
        assert!(ablation_dataflow().render().contains("OS/WS"));
        assert!(ablation_scale().render().contains("TOPS/W"));
        assert!(ablation_dividers(&ItaConfig::paper()).render().contains("dividers"));
    }
}
