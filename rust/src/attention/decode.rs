//! Incremental (autoregressive) decode: per-head KV caches plus a
//! decode engine that computes one new token's attention in O(S) work
//! per step — the serving-side expression of ITA's streaming softmax
//! (paper §IV), whose per-row `MAX`/`Σ` state machine is exactly what
//! append-only decode needs.
//!
//! # Dataflow per step (one head)
//!
//! 1. Project only the new row: `q/k/v = requant(x·W + b)` via
//!    [`TileEngine::linear_row_pret`] (the weight-stationary transposed
//!    weights are shared with the prefill path).
//! 2. Append `k`/`v` to the head's [`KvCache`] (K row-major for
//!    Q·Kᵀ-ready row dots; V packed transposed for the A·V dots).
//! 3. Logit row against all cached keys, then the streaming softmax
//!    over the completed row — DA in M-wide parts with the single-shift
//!    renormalization `Σ >>= Δ >> 5` when a later part raises the row
//!    maximum, DI, EN ([`TileEngine::softmax_row`]).
//! 4. A·V against the cached Vᵀ pack, heads concatenated, output
//!    projection.
//!
//! Every step is **bit-identical** to the matching row of re-running
//! the full causal path ([`TileEngine::attention_core_causal`] through
//! [`super::run_attention_causal`]) over the grown sequence — pinned by
//! `tests/decode_parity.rs` — while doing O(S) instead of O(S²) work
//! and allocating nothing in steady state (`tests/decode_alloc.rs`
//! counts allocations under a counting global allocator).

use super::{
    concat_heads, run_causal_heads, AttentionOutput, AttentionWeights, HeadWeights, ModelDims,
    PackedWeights, RequantConfig, TransposedWeights,
};
use crate::ita::datapath::TileEngine;
use crate::ita::{Activity, ItaConfig};
use crate::util::blocks::{Block, BlockArena, BlockPoolExhausted, DEFAULT_KV_BLOCK};
use crate::util::mat::{MatI8, MatU8};
use crate::util::pool::{DisjointSlots, IndexedScope, ScopeFailure, Task, WorkerPool};
use std::sync::Arc;

/// One head's append-only K/V store, **paged**: backed by fixed-size
/// [`Block`]s drawn on demand from a [`BlockArena`] instead of one
/// worst-case-capacity contiguous reservation.
///
/// Within a block, K is kept row-major (one row per cached position,
/// the layout Q·Kᵀ row dots want) and V is kept transposed (P rows of
/// `block_size` each, the layout the A·V row dots want), so a step's
/// reads are contiguous block-local slices. [`KvCache::truncate`]
/// rolls the logical length back without touching storage *or*
/// returning blocks — the rollback primitive speculative decoding
/// (and the decode bench) needs stays replay-exact and
/// allocation-free. [`KvCache::release_blocks`] is the serving-layer
/// primitive that does return everything (close / eviction /
/// preemption); `Drop` reclaims too, so a dropped session can never
/// leak pool blocks.
///
/// # Prefix sharing and copy-on-write
///
/// A table entry may be **shared** (its [`Block`] handle refcounts the
/// same physical storage as another cache's entry or a router
/// prefix-cache entry — [`KvCache::adopt`] / [`KvCache::share_blocks`])
/// or **owned** (refcount 1). Reads never care: the O(S) attend tail
/// walks both identically, byte-for-byte. Writes do: any append
/// landing in a shared block first **forks** it — a fallible
/// `try_alloc` draw, a memcpy of the retained rows, a table-entry
/// swap that drops (refcount-decrements) the parent handle. The fork
/// runs in the fallible [`KvCache::reserve`] phase, so CoW pressure
/// surfaces as the same [`BlockPoolExhausted`] the serving layer
/// already contains via deferred admission / preemption; the
/// `kv.cow.fork` failpoint (ctx = `fail_tag`) injects exhaustion or a
/// panic at exactly that moment.
#[derive(Debug)]
pub struct KvCache {
    /// Block table: block `b` holds positions `b·bs .. (b+1)·bs`.
    /// Each entry is a refcounted handle — exclusively owned entries
    /// are writable, shared entries are read-only until CoW-forked —
    /// so the fused tick's per-session fan-out still runs lock- and
    /// unsafe-free (forks happen in the serial reserve phase).
    blocks: Vec<Block>,
    arena: Arc<BlockArena>,
    len: usize,
    capacity: usize,
    /// Fault-injection targeting tag for the `kv.cow.fork` failpoint
    /// (propagated from the owning engine's `fail_tag` on every
    /// [`DecodeEngine::reserve_for`]). Inert unless `failpoints` is on.
    fail_tag: u64,
}

impl KvCache {
    /// Stand-alone cache over a **private** arena sized to exactly
    /// cover `capacity` — the single-engine construction (tests,
    /// examples, golden oracles), where exhaustion is impossible by
    /// construction. Serving paths share one bounded arena via
    /// [`KvCache::with_arena`] instead.
    pub fn new(capacity: usize, p: usize) -> Self {
        let bs = DEFAULT_KV_BLOCK.min(capacity).max(1);
        let arena = BlockArena::new(bs, p, capacity.div_ceil(bs));
        Self::with_arena(arena, capacity)
    }

    /// Cache drawing its blocks from `arena` (shared or private).
    /// Nothing is allocated yet — blocks arrive on demand via
    /// [`KvCache::reserve`] / [`KvCache::push`]. The block-table `Vec`
    /// is pre-sized so growth to full capacity never reallocates it.
    pub fn with_arena(arena: Arc<BlockArena>, capacity: usize) -> Self {
        let table = arena.blocks_for(capacity);
        Self { blocks: Vec::with_capacity(table), arena, len: 0, capacity, fail_tag: 0 }
    }

    #[inline]
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Positions per backing block.
    #[inline]
    pub fn block_size(&self) -> usize {
        self.arena.block_size()
    }

    /// The owned block table (block `b` = positions `b·bs..(b+1)·bs`;
    /// only positions `0..len()` are meaningful).
    #[inline]
    pub fn blocks(&self) -> &[Block] {
        &self.blocks
    }

    /// The arena this cache draws from.
    #[inline]
    pub fn arena(&self) -> &Arc<BlockArena> {
        &self.arena
    }

    /// Ensure the block table covers `new_len` positions — drawing
    /// blocks from the arena AND copy-on-write-forking any **shared**
    /// existing block the appends `len..new_len` would write into —
    /// the **fallible** path the serving layer uses to turn pool
    /// exhaustion into deferred admission or preemption instead of a
    /// panic. On failure the table is left trimmed back to what `len`
    /// needs (no freshly-drawn block is stranded on a cache that could
    /// not grow; an already-completed fork is harmless — the forked
    /// entry is owned and bit-identical to its parent's retained
    /// rows).
    pub fn reserve(&mut self, new_len: usize) -> Result<(), BlockPoolExhausted> {
        assert!(new_len <= self.capacity, "reserve beyond cache capacity {}", self.capacity);
        let bs = self.block_size();
        if new_len > self.len {
            // CoW: the appends will write blocks len/bs ..= (new_len-1)/bs;
            // fork every one of those that already exists and is shared
            // (typically just the partial tail block of an adopted
            // prefix — but truncate-and-replay can also land a rewrite
            // in an earlier shared block).
            let last = (new_len - 1) / bs;
            for idx in (self.len / bs)..=last {
                if idx >= self.blocks.len() {
                    break;
                }
                if self.blocks[idx].is_shared() {
                    self.cow_fork(idx)?;
                }
            }
        }
        while self.blocks.len() * bs < new_len {
            match self.arena.try_alloc() {
                Ok(b) => self.blocks.push(b),
                Err(e) => {
                    self.trim_to_len();
                    return Err(e);
                }
            }
        }
        Ok(())
    }

    /// Copy-on-write fork of table entry `idx`: draw a fresh block
    /// (fallible), memcpy the retained rows (`len`-covered positions
    /// of that block — later positions hold no live data), swap the
    /// table entry, and drop the parent handle (refcount decrement;
    /// the physical parent stays alive for its other sharers). The
    /// `kv.cow.fork` failpoint (ctx = `fail_tag`) fires first, so
    /// chaos tests can inject exhaustion or a panic mid-fork.
    fn cow_fork(&mut self, idx: usize) -> Result<(), BlockPoolExhausted> {
        if crate::util::failpoint::hit("kv.cow.fork", self.fail_tag) {
            return Err(BlockPoolExhausted { total_blocks: self.arena.total_blocks() });
        }
        let mut fresh = self.arena.try_alloc()?;
        let bs = self.block_size();
        let retained = self.len.saturating_sub(idx * bs).min(bs);
        {
            let parent = &self.blocks[idx];
            let dst = fresh.storage_mut();
            for r in 0..retained {
                dst.k.row_mut(r).copy_from_slice(parent.k.row(r));
            }
            for j in 0..self.arena.p() {
                dst.vt.row_mut(j)[..retained].copy_from_slice(&parent.vt.row(j)[..retained]);
            }
        }
        self.arena.note_cow_fork();
        self.blocks[idx] = fresh;
        Ok(())
    }

    /// Seed an **empty** cache with shared handles to `blocks`
    /// (refcount bumps, no data movement, no arena draw): afterwards
    /// positions `0..rows` read the donor's cached bytes. The partial
    /// tail block (when `rows % block_size != 0`) stays shared too —
    /// the first append into it CoW-forks. The handle clones are
    /// pushed into the pre-sized table, so adoption allocates nothing.
    pub fn adopt(&mut self, blocks: &[Block], rows: usize) {
        assert!(self.is_empty() && self.blocks.is_empty(), "adopt into a non-empty cache");
        assert!(rows <= self.capacity, "adopt beyond cache capacity {}", self.capacity);
        assert_eq!(
            blocks.len(),
            self.arena.blocks_for(rows),
            "adopted block count must exactly cover {rows} rows"
        );
        for b in blocks {
            assert_eq!(b.k.rows(), self.block_size(), "foreign block (size)");
            assert_eq!(b.k.cols(), self.arena.p(), "foreign block (width)");
            self.blocks.push(b.share());
        }
        self.len = rows;
    }

    /// Shared handles to the blocks covering positions `0..rows` —
    /// what a prefix-cache entry (or another session's
    /// [`KvCache::adopt`]) retains. Refcount bumps only; this cache's
    /// entries keep working unchanged (they just become CoW-on-append).
    pub fn share_blocks(&self, rows: usize) -> Vec<Block> {
        assert!(rows <= self.len, "share beyond cached length {}", self.len);
        self.blocks[..self.arena.blocks_for(rows)].iter().map(|b| b.share()).collect()
    }

    /// Return every block beyond what `len` needs (the failed-
    /// reservation rollback; such blocks hold no live data).
    fn trim_to_len(&mut self) {
        while self.blocks.len() > self.arena.blocks_for(self.len) {
            let b = self.blocks.pop().expect("table longer than len cover");
            self.arena.reclaim(b);
        }
    }

    /// Append one (key row, value row) pair. Panics when full — the
    /// serving layer checks capacity before admitting a step. Draws a
    /// block if the table doesn't cover the new position, and
    /// CoW-forks a covered-but-shared target block; on a *shared*
    /// arena the serving layer reserves first ([`KvCache::reserve`],
    /// which also performs the forks fallibly), making both paths here
    /// infallible — the `expect`s are the backstop for solo paths that
    /// skipped reservation (their private arenas cover capacity by
    /// construction).
    pub fn push(&mut self, k_row: &[i8], v_row: &[i8]) {
        assert!(self.len < self.capacity, "KV cache full (capacity {})", self.capacity);
        assert_eq!(k_row.len(), self.arena.p(), "key row width");
        assert_eq!(v_row.len(), self.arena.p(), "value row width");
        let bs = self.block_size();
        if self.len == self.blocks.len() * bs {
            let b = self.arena.try_alloc().expect("KV block pool exhausted (reserve first)");
            self.blocks.push(b);
        } else if self.blocks[self.len / bs].is_shared() {
            self.cow_fork(self.len / bs)
                .expect("KV block pool exhausted on CoW fork (reserve first)");
        }
        let b = self.blocks[self.len / bs].storage_mut();
        let slot = self.len % bs;
        b.k.row_mut(slot).copy_from_slice(k_row);
        for (j, &v) in v_row.iter().enumerate() {
            b.vt.set(j, slot, v);
        }
        self.len += 1;
    }

    /// Roll the logical length back to `len` (≤ current). Storage for
    /// positions `0..len` is untouched, so re-appending reproduces the
    /// original sequence bit-for-bit. Blocks beyond the rollback point
    /// are **retained** (they stay this session's reserved capacity),
    /// keeping truncate-and-replay allocation-free and arena-silent.
    pub fn truncate(&mut self, len: usize) {
        assert!(len <= self.len, "truncate beyond current length");
        self.len = len;
    }

    /// Return every block to the arena and empty the cache — the
    /// close/evict/preempt primitive. The cached bytes are gone; a
    /// preempted session restores them by recompute-prefill.
    pub fn release_blocks(&mut self) {
        self.len = 0;
        for b in self.blocks.drain(..) {
            self.arena.reclaim(b);
        }
    }

    /// One cached key row (contiguous: a key row never straddles
    /// blocks).
    #[inline]
    pub fn k_row(&self, i: usize) -> &[i8] {
        assert!(i < self.len, "key row {i} beyond cache length {}", self.len);
        let bs = self.block_size();
        self.blocks[i / bs].k.row(i % bs)
    }

    /// One cached value row, gathered from the transposed pack
    /// (allocates — a test/debug accessor, not a serving path).
    pub fn v_col(&self, i: usize) -> Vec<i8> {
        assert!(i < self.len, "value row {i} beyond cache length {}", self.len);
        let bs = self.block_size();
        let b = &self.blocks[i / bs];
        (0..self.arena.p()).map(|j| b.vt.get(j, i % bs)).collect()
    }
}

impl Drop for KvCache {
    fn drop(&mut self) {
        self.release_blocks();
    }
}

/// Generation-capable attention engine: prefill once, then O(S)-work
/// incremental steps against per-head KV caches. Capacity (and the
/// deterministic requant derivation) comes from `dims` — `dims.s` is
/// the maximum sequence length a session can grow to.
pub struct DecodeEngine {
    pub engine: TileEngine,
    /// Shared with every other session serving the same model
    /// (weights are read-only at serve time).
    pub weights: Arc<AttentionWeights>,
    pub weights_t: Arc<TransposedWeights>,
    pub requants: RequantConfig,
    pub dims: ModelDims,
    /// Fault-injection targeting tag (chaos harness): the coordinator
    /// sets this to the owning session id so a `decode.step.tail`
    /// failpoint can single out one session inside a fused tick.
    /// Inert (0, never read) unless the `failpoints` feature is on.
    pub fail_tag: u64,
    caches: Vec<KvCache>,
    // Flat scratch fields (disjoint borrows with `engine`/`caches`),
    // all sized at construction so steps never allocate.
    q_row: Vec<i8>,
    k_row: Vec<i8>,
    v_row: Vec<i8>,
    logits: Vec<i8>,
    /// Per-head probability row of the most recent step (exposed for
    /// tests / the Fig. 5-style experiments).
    attn_rows: Vec<Vec<u8>>,
    concat: Vec<i8>,
    /// Concat rows of the most recent prefill chunk (chunk_rows×H·P —
    /// §Chunked-prefill): the fused tick gathers these for the shared
    /// output projection, exactly as `concat` serves the R=1 steps.
    chunk_concat: MatI8,
}

impl DecodeEngine {
    /// Deterministic construction mirroring [`super::AttentionExecutor::new`]:
    /// the same seed serves the same model — through the
    /// [`PackedWeights`] cache, so a decode engine and an executor for
    /// the same `(seed, dims)` share one generated-and-packed weight
    /// set (§Perf: no per-engine regeneration or re-transpose).
    pub fn new(cfg: ItaConfig, dims: ModelDims, seed: u64) -> Self {
        let packed = PackedWeights::shared(dims, seed);
        Self::from_shared(
            cfg,
            dims,
            packed.weights.clone(),
            packed.weights_t.clone(),
            packed.requants,
        )
    }

    /// Build around an existing shared model (multi-session serving:
    /// every session clones the `Arc`s instead of regenerating and
    /// re-transposing the weights — only the KV caches and scratch are
    /// per-session). The KV blocks come from a **private** arena sized
    /// to exactly cover H heads × capacity, so this engine can never
    /// see pool exhaustion; the memory-pressure serving paths share
    /// one bounded arena via [`DecodeEngine::from_shared_arena`].
    pub fn from_shared(
        cfg: ItaConfig,
        dims: ModelDims,
        weights: Arc<AttentionWeights>,
        weights_t: Arc<TransposedWeights>,
        requants: RequantConfig,
    ) -> Self {
        let bs = DEFAULT_KV_BLOCK.min(dims.s).max(1);
        let arena = BlockArena::new(bs, dims.p, dims.h * dims.s.div_ceil(bs));
        Self::from_shared_arena(cfg, dims, weights, weights_t, requants, arena)
    }

    /// [`DecodeEngine::from_shared`] drawing KV blocks from a caller-
    /// provided (typically process-shared, bounded) [`BlockArena`] —
    /// the paged-serving construction. The caller owns the exhaustion
    /// story: reserve before stepping ([`DecodeEngine::reserve_for`])
    /// and release on close/evict/preempt
    /// ([`DecodeEngine::release_blocks`], also run by drop).
    pub fn from_shared_arena(
        cfg: ItaConfig,
        dims: ModelDims,
        weights: Arc<AttentionWeights>,
        weights_t: Arc<TransposedWeights>,
        requants: RequantConfig,
        arena: Arc<BlockArena>,
    ) -> Self {
        assert!(dims.h >= 1, "at least one head");
        assert_eq!(weights.heads.len(), dims.h, "weights/dims head count");
        assert_eq!(weights_t.heads.len(), dims.h, "transposed weights/dims head count");
        assert_eq!(arena.p(), dims.p, "arena block width must match the projection width");
        Self {
            engine: TileEngine::new(cfg),
            weights,
            weights_t,
            requants,
            dims,
            fail_tag: 0,
            caches: (0..dims.h).map(|_| KvCache::with_arena(arena.clone(), dims.s)).collect(),
            q_row: vec![0; dims.p],
            k_row: vec![0; dims.p],
            v_row: vec![0; dims.p],
            logits: Vec::with_capacity(dims.s),
            attn_rows: (0..dims.h).map(|_| Vec::with_capacity(dims.s)).collect(),
            concat: vec![0; dims.h * dims.p],
            chunk_concat: MatI8::zeros(0, 0),
        }
    }

    /// Current sequence length (cache fill).
    pub fn len(&self) -> usize {
        self.caches[0].len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Maximum sequence length (`dims.s`).
    pub fn capacity(&self) -> usize {
        self.dims.s
    }

    /// Per-head caches (read-only view).
    pub fn caches(&self) -> &[KvCache] {
        &self.caches
    }

    /// Probability row of the most recent step for `head` (length =
    /// the sequence length at that step).
    pub fn last_attn_row(&self, head: usize) -> &[u8] {
        &self.attn_rows[head]
    }

    /// Roll every head's cache back to `len` (speculative-decode
    /// rollback; also lets benches re-measure a step at a fixed fill).
    pub fn truncate(&mut self, len: usize) {
        for c in &mut self.caches {
            c.truncate(len);
        }
    }

    /// Empty all caches; the engine is ready for a fresh prefill.
    /// Blocks stay reserved ([`KvCache::truncate`] semantics) — use
    /// [`DecodeEngine::release_blocks`] to also return them.
    pub fn reset(&mut self) {
        self.truncate(0);
    }

    /// The arena every head's cache draws from.
    pub fn arena(&self) -> &Arc<BlockArena> {
        self.caches[0].arena()
    }

    /// Fallibly ensure every head's block table covers `new_len`
    /// positions — the serving layer's pre-step/pre-prefill gate that
    /// turns pool exhaustion into a recoverable
    /// [`BlockPoolExhausted`]. This is also where copy-on-write forks
    /// run ([`KvCache::reserve`]): any shared block the coming appends
    /// would write into is forked here, fallibly and serially, before
    /// any compute. On failure, blocks already drawn for this
    /// reservation are returned (per-cache trim), so a failed
    /// reservation strands nothing; completed forks persist (owned,
    /// bit-identical retained rows — harmless).
    pub fn reserve_for(&mut self, new_len: usize) -> Result<(), BlockPoolExhausted> {
        for i in 0..self.caches.len() {
            // Keep the cow-fork failpoint aimed at this session.
            self.caches[i].fail_tag = self.fail_tag;
            if let Err(e) = self.caches[i].reserve(new_len) {
                // Roll the earlier heads' fresh draws back too — a
                // failed reservation must not shrink the pool for the
                // sessions that could still make progress.
                for c in &mut self.caches[..i] {
                    c.trim_to_len();
                }
                return Err(e);
            }
        }
        Ok(())
    }

    /// Seed every (empty) head cache with shared handles to another
    /// engine's prefix blocks (`blocks[h]` = head `h`'s covering
    /// blocks, from [`DecodeEngine::share_prefix`]): afterwards
    /// `len() == rows` and the next prefill chunk continues from row
    /// `rows` — the adopted positions read the donor's bytes, so the
    /// continuation is bit-identical to having prefilled them locally
    /// (pinned by `tests/prefix_sharing.rs`). Refcount bumps only; no
    /// pool draw, no copy.
    pub fn adopt_prefix(&mut self, blocks: &[Vec<Block>], rows: usize) {
        assert_eq!(blocks.len(), self.dims.h, "one shared block set per head");
        assert!(self.is_empty(), "adopt into a non-empty engine (release_blocks() first)");
        for (c, bs) in self.caches.iter_mut().zip(blocks) {
            c.adopt(bs, rows);
        }
    }

    /// Shared handles to every head's blocks covering positions
    /// `0..rows` — what the router's prefix cache retains at prefill
    /// completion (and what a matching admission adopts).
    pub fn share_prefix(&self, rows: usize) -> Vec<Vec<Block>> {
        assert!(rows <= self.len(), "share beyond cached length {}", self.len());
        self.caches.iter().map(|c| c.share_blocks(rows)).collect()
    }

    /// Return every head's blocks to the arena and empty the caches —
    /// close, eviction, and **preemption** all funnel here. The engine
    /// stays usable: a later recompute-prefill restores the cache
    /// bytes bit-identically.
    pub fn release_blocks(&mut self) {
        for c in &mut self.caches {
            c.release_blocks();
        }
    }

    /// Prompt phase: run the full causal path over `x` (S₀×E, S₀ ≤
    /// capacity), filling every head's cache with the projected K/V
    /// rows. Output is bit-identical to
    /// [`super::run_attention_causal`] over `x` (same kernels, cached
    /// pre-transposed weights).
    pub fn prefill(&mut self, x: &MatI8) -> AttentionOutput {
        assert_eq!(x.cols(), self.dims.e, "prefill row width");
        assert!(self.is_empty(), "prefill on a non-empty cache (reset() first)");
        assert!(x.rows() <= self.capacity(), "prompt longer than cache capacity");
        let rq = self.requants;
        let caches = &mut self.caches;
        let wt = &self.weights_t;
        let (head_outputs, attn) =
            run_causal_heads(&mut self.engine, &self.weights, &rq, |e, h, hw| {
                let (wqt, wkt, wvt) = &wt.heads[h];
                let q = e.linear_pret(x, wqt, &hw.bq, rq.q);
                let k = e.linear_pret(x, wkt, &hw.bk, rq.k);
                let v = e.linear_pret(x, wvt, &hw.bv, rq.v);
                for r in 0..x.rows() {
                    caches[h].push(k.row(r), v.row(r));
                }
                (q, k, v)
            });
        let out = self.engine.linear_pret(
            &concat_heads(&head_outputs),
            &self.weights_t.wot,
            &self.weights.bo,
            rq.o,
        );
        AttentionOutput { out, attn }
    }

    /// Prompt phase from **pre-projected** per-head Q/K/V matrices
    /// (§Prefill-batching): the fused multi-session prefill computes
    /// one stacked GEMM per projection weight across all sessions, then
    /// hands each session its row slices here. This method still owns
    /// everything per-session — it fills the KV caches from the K/V
    /// rows and runs the causal logits → streaming softmax → A·V core
    /// per head on the session's own engine — so its outputs (and the
    /// caches it leaves behind) are bit-identical to
    /// [`DecodeEngine::prefill`] over the same prompt.
    ///
    /// Returns the concatenated head outputs (S₀×H·P — the fused
    /// caller stacks these for the one shared output projection) and
    /// the per-head attention matrices. Only the causal-core activity
    /// lands on `self.engine`; the caller attributes each session's
    /// share of the fused projection passes.
    pub fn prefill_from_projected(
        &mut self,
        qkv: &[(MatI8, MatI8, MatI8)],
    ) -> (MatI8, Vec<MatU8>) {
        assert_eq!(qkv.len(), self.dims.h, "one Q/K/V triple per head");
        assert!(self.is_empty(), "prefill on a non-empty cache (reset() first)");
        let rows = qkv[0].0.rows();
        assert!(rows <= self.capacity(), "prompt longer than cache capacity");
        let rq = self.requants;
        let weights = self.weights.clone();
        let mut head_outputs = Vec::with_capacity(self.dims.h);
        let mut attn = Vec::with_capacity(self.dims.h);
        for (h, ((q, k, v), hw)) in qkv.iter().zip(weights.heads.iter()).enumerate() {
            assert_eq!(q.rows(), rows, "head {h} Q rows");
            assert_eq!(k.rows(), rows, "head {h} K rows");
            assert_eq!(v.rows(), rows, "head {h} V rows");
            assert_eq!(q.cols(), self.dims.p, "head {h} projection width");
            for r in 0..rows {
                self.caches[h].push(k.row(r), v.row(r));
            }
            let (o, a) = self.engine.attention_core_causal(q, k, v, rq.qk, &hw.bav, rq.av);
            head_outputs.push(o);
            attn.push(a);
        }
        (concat_heads(&head_outputs), attn)
    }

    /// One decode step: append token row `x_row` (length E) and write
    /// its output row (length E) into `out` — bit-identical to row
    /// `len()` of the full causal recompute over the grown sequence.
    /// O(S) work; no allocation once `out`'s capacity covers E.
    pub fn step_into(&mut self, x_row: &[i8], out: &mut Vec<i8>) {
        assert_eq!(x_row.len(), self.dims.e, "token row width");
        assert!(self.len() < self.capacity(), "KV cache full");
        let _ = crate::util::failpoint::hit("decode.step.tail", self.fail_tag);
        let rq = self.requants;
        let p = self.dims.p;
        for (h, (hw, wts)) in self.weights.heads.iter().zip(&self.weights_t.heads).enumerate() {
            let (wqt, wkt, wvt) = wts;
            self.engine.linear_row_pret(x_row, wqt, &hw.bq, rq.q, &mut self.q_row);
            self.engine.linear_row_pret(x_row, wkt, &hw.bk, rq.k, &mut self.k_row);
            self.engine.linear_row_pret(x_row, wvt, &hw.bv, rq.v, &mut self.v_row);
            attend_tail(
                &mut self.engine,
                &mut self.caches[h],
                hw,
                &rq,
                &self.q_row,
                &self.k_row,
                &self.v_row,
                &mut self.logits,
                &mut self.attn_rows[h],
                &mut self.concat[h * p..(h + 1) * p],
            );
        }
        self.engine.linear_row_pret(
            &self.concat,
            &self.weights_t.wot,
            &self.weights.bo,
            rq.o,
            out,
        );
    }

    /// Allocating convenience wrapper around [`DecodeEngine::step_into`].
    pub fn step(&mut self, x_row: &[i8]) -> Vec<i8> {
        let mut out = Vec::with_capacity(self.dims.e);
        self.step_into(x_row, &mut out);
        out
    }

    /// The attend half of one step, from **pre-projected** per-head
    /// Q/K/V rows (§Step-batching): the fused tick computed this
    /// step's q/k/v in one stacked R=N GEMM per weight; `qkv[h]` holds
    /// that batch-wide N×P stack for head `h` and `row` is this
    /// session's row in it. Runs everything per-session — cache
    /// append, logit row against the cached keys, streaming softmax,
    /// A·V against the cached Vᵀ pack — through the exact same tail
    /// ([`attend_tail`]) as [`DecodeEngine::step_into`], so caches,
    /// attention rows, and the concat scratch come out bit-identical.
    /// The concatenated head outputs land in [`DecodeEngine::last_concat`];
    /// the caller owns the (fused) output projection. Only the tail's
    /// activity lands on `self.engine` — the caller attributes this
    /// session's share of the fused projection passes.
    pub fn step_from_projected(&mut self, qkv: &[(MatI8, MatI8, MatI8)], row: usize) {
        assert_eq!(qkv.len(), self.dims.h, "one stacked Q/K/V triple per head");
        assert!(self.len() < self.capacity(), "KV cache full");
        let _ = crate::util::failpoint::hit("decode.step.tail", self.fail_tag);
        let rq = self.requants;
        let p = self.dims.p;
        for (h, ((q, k, v), hw)) in qkv.iter().zip(self.weights.heads.iter()).enumerate() {
            assert!(row < q.rows(), "head {h} row {row} beyond stacked Q rows");
            assert_eq!(k.rows(), q.rows(), "head {h} K rows");
            assert_eq!(v.rows(), q.rows(), "head {h} V rows");
            assert_eq!(q.cols(), p, "head {h} projection width");
            attend_tail(
                &mut self.engine,
                &mut self.caches[h],
                hw,
                &rq,
                q.row(row),
                k.row(row),
                v.row(row),
                &mut self.logits,
                &mut self.attn_rows[h],
                &mut self.concat[h * p..(h + 1) * p],
            );
        }
    }

    /// Concatenated head outputs (H·P) of the most recent step — the
    /// input row of the output projection. Exposed for the fused-step
    /// caller, which stacks these rows across sessions for the one
    /// shared output projection.
    pub fn last_concat(&self) -> &[i8] {
        &self.concat
    }

    /// One **prefill chunk** from pre-projected per-head Q/K/V stacks
    /// (§Chunked-prefill): append `rows` prompt rows starting at stack
    /// row `base`, each processed through the exact same per-row tail
    /// ([`attend_tail`]) as a decode step — cache append, causal logit
    /// row against everything cached so far, streaming softmax, A·V.
    /// This IS the resumable partial-prefill state: cache fill is the
    /// chunk cursor, and because row `len()` of a causal prefill
    /// attends to positions `0..=len()` exactly as a decode step does,
    /// chunked caches/outputs are bit-identical to one monolithic
    /// [`DecodeEngine::prefill`] regardless of chunk boundaries
    /// (pinned by `tests/prefill_chunked.rs`).
    ///
    /// The per-row concat outputs land in rows `0..rows` of the
    /// engine's chunk-concat scratch for the fused caller's shared
    /// output projection; [`DecodeEngine::last_concat`] is untouched.
    /// Only the tail activity lands on `self.engine` — the caller
    /// attributes this session's share of the fused projections.
    ///
    /// Fault injection: hits the `prefill.chunk` failpoint once per
    /// chunk (ctx = `fail_tag`), the chunk-granular mirror of
    /// `decode.step.tail`.
    pub fn prefill_chunk_from_projected(
        &mut self,
        qkv: &[(MatI8, MatI8, MatI8)],
        base: usize,
        rows: usize,
    ) {
        assert_eq!(qkv.len(), self.dims.h, "one stacked Q/K/V triple per head");
        assert!(rows >= 1, "empty prefill chunk");
        assert!(self.len() + rows <= self.capacity(), "chunk beyond cache capacity");
        let (h, p) = (self.dims.h, self.dims.p);
        // Sized before the failpoint so a panicking chunk still leaves
        // a `rows`-row scratch for the fused caller's (unread) gather.
        self.chunk_concat.reset_for_overwrite(rows, h * p);
        let _ = crate::util::failpoint::hit("prefill.chunk", self.fail_tag);
        let rq = self.requants;
        let weights = self.weights.clone();
        for (hh, (q, k, v)) in qkv.iter().enumerate() {
            assert!(base + rows <= q.rows(), "head {hh} chunk beyond stacked Q rows");
            assert_eq!(k.rows(), q.rows(), "head {hh} K rows");
            assert_eq!(v.rows(), q.rows(), "head {hh} V rows");
            assert_eq!(q.cols(), p, "head {hh} projection width");
        }
        for j in 0..rows {
            for (hh, ((q, k, v), hw)) in qkv.iter().zip(weights.heads.iter()).enumerate() {
                attend_tail(
                    &mut self.engine,
                    &mut self.caches[hh],
                    hw,
                    &rq,
                    q.row(base + j),
                    k.row(base + j),
                    v.row(base + j),
                    &mut self.logits,
                    &mut self.attn_rows[hh],
                    &mut self.concat[hh * p..(hh + 1) * p],
                );
            }
            self.chunk_concat.row_mut(j).copy_from_slice(&self.concat);
        }
    }

    /// Standalone (self-projecting) prefill chunk: project `x`'s rows
    /// through this engine's own Q/K/V weights, advance the caches by
    /// [`DecodeEngine::prefill_chunk_from_projected`], and return the
    /// chunk's output rows (rows×E) through the output projection —
    /// the solo mirror of one fused-tick chunk member, and the oracle
    /// building block of `tests/prefill_chunked.rs`. Concatenating
    /// these outputs over any chunking of a prompt reproduces
    /// [`DecodeEngine::prefill`]'s output matrix bit for bit.
    pub fn prefill_chunk(&mut self, x: &MatI8) -> MatI8 {
        assert_eq!(x.cols(), self.dims.e, "chunk row width");
        assert!(x.rows() >= 1, "empty prefill chunk");
        let rq = self.requants;
        let weights = self.weights.clone();
        let weights_t = self.weights_t.clone();
        let mut qkv = Vec::with_capacity(self.dims.h);
        for (hw, wts) in weights.heads.iter().zip(&weights_t.heads) {
            let (wqt, wkt, wvt) = wts;
            let q = self.engine.linear_pret(x, wqt, &hw.bq, rq.q);
            let k = self.engine.linear_pret(x, wkt, &hw.bk, rq.k);
            let v = self.engine.linear_pret(x, wvt, &hw.bv, rq.v);
            qkv.push((q, k, v));
        }
        self.prefill_chunk_from_projected(&qkv, 0, x.rows());
        self.engine.linear_pret(&self.chunk_concat, &weights_t.wot, &weights.bo, rq.o)
    }
}

/// The per-head O(S) cache-attention tail of one decode step: cache
/// append, logit row vs the cached keys, streaming softmax, A·V vs
/// the cached Vᵀ pack. ONE body shared by [`DecodeEngine::step_into`]
/// (which projected q/k/v itself) and
/// [`DecodeEngine::step_from_projected`] (whose projections came from
/// the fused stacked GEMM) — bit-identical tails by construction.
#[allow(clippy::too_many_arguments)]
fn attend_tail(
    engine: &mut TileEngine,
    cache: &mut KvCache,
    hw: &HeadWeights,
    rq: &RequantConfig,
    q_row: &[i8],
    k_row: &[i8],
    v_row: &[i8],
    logits: &mut Vec<i8>,
    attn_row: &mut Vec<u8>,
    concat_slot: &mut [i8],
) {
    cache.push(k_row, v_row);
    engine.logits_row_paged(q_row, cache.blocks(), cache.block_size(), cache.len(), rq.qk, logits);
    engine.softmax_row(logits, attn_row);
    engine.av_row_paged(attn_row, cache.blocks(), cache.block_size(), &hw.bav, rq.av, concat_slot);
}

/// Result of one [`fused_prefill`] pass.
pub struct FusedPrefillResult {
    /// Per-session causal outputs in input order — bit-identical to
    /// what each session's independent [`DecodeEngine::prefill`] would
    /// have returned.
    pub outputs: Vec<AttentionOutput>,
    /// The batch-shared activity: the once-per-batch projection weight
    /// streams (3·H + 1 weight matrices, `weight_buf_writes` only).
    /// Everything per-session lands on each engine's
    /// `engine.activity`, which this call resets and repopulates.
    pub shared: Activity,
}

/// Fused multi-session prefill (§Prefill-batching): stack the prompt
/// rows of N sessions serving the **same** [`PackedWeights`] into one
/// tall activation matrix and run a **single** blocked GEMM per
/// projection weight (per head Wq/Wk/Wv, plus Wo for the output
/// projection) via [`TileEngine::linear_pret_multi`] — N prefills cost
/// one weight stream per matrix instead of N. Everything that is
/// per-session — KV-cache fills, causal logits, streaming softmax,
/// A·V — still runs on each session's own engine
/// ([`DecodeEngine::prefill_from_projected`]), so every output, cache,
/// and attention row is bit-identical to N independent prefills
/// (pinned by `tests/prefill_fused.rs` across ragged lengths and all
/// dispatch paths).
///
/// Execution fans out over the process [`WorkerPool`]: first one task
/// per head for the fused Q/K/V projections, then one task per
/// session for the causal cores, then the single fused output
/// projection — the per-session stage pipelines behind the shared
/// GEMMs without any per-batch thread spawns.
///
/// Accounting: each engine's activity is reset and left holding that
/// session's share of the whole pass — its causal core plus its
/// row-slice share of every projection GEMM, weight streams excluded.
/// The streams are charged once per batch in
/// [`FusedPrefillResult::shared`] (the M-row tile-padding argument:
/// fusion amortizes the weight streams; each sequence keeps its own
/// row-tile padding so per-session charges are composition-invariant).
pub fn fused_prefill(
    engines: &mut [&mut DecodeEngine],
    inputs: &[&MatI8],
) -> FusedPrefillResult {
    let n = engines.len();
    assert_eq!(n, inputs.len(), "one prompt per session");
    assert!(n >= 1, "fused prefill needs at least one session");
    let dims = engines[0].dims;
    let cfg = engines[0].engine.cfg;
    let rq = engines[0].requants;
    let weights = engines[0].weights.clone();
    let weights_t = engines[0].weights_t.clone();
    for (i, (e, x)) in engines.iter().zip(inputs).enumerate() {
        assert!(
            Arc::ptr_eq(&e.weights, &weights) && Arc::ptr_eq(&e.weights_t, &weights_t),
            "fused prefill requires every session to share one packed model (session {i})"
        );
        // The per-sequence Activity shares are computed with one tile
        // geometry — a session with a different ItaConfig would be
        // silently mis-charged, so reject it loudly.
        assert!(
            e.engine.cfg == cfg,
            "fused prefill requires every session to share one ItaConfig (session {i})"
        );
        assert!(e.is_empty(), "fused prefill on a non-empty cache (session {i}; reset() first)");
        assert_eq!(x.cols(), dims.e, "prompt row width (session {i})");
        assert!(x.rows() <= e.capacity(), "prompt longer than cache capacity (session {i})");
    }

    let lens: Vec<usize> = inputs.iter().map(|x| x.rows()).collect();
    let mut offsets = Vec::with_capacity(n);
    let mut m_total = 0usize;
    for &l in &lens {
        offsets.push(m_total);
        m_total += l;
    }
    let mut x_all = MatI8::zeros(m_total, dims.e);
    for (x, &off) in inputs.iter().zip(&offsets) {
        for r in 0..x.rows() {
            x_all.row_mut(off + r).copy_from_slice(x.row(r));
        }
    }

    // ---- Stage 1: one fused GEMM per projection weight --------------
    // One pool task per head (its three weight matrices are streamed
    // back to back on a task-private engine); the per-sequence /
    // shared Activity splits merge afterwards — pure counter sums, so
    // placement is invisible.
    struct HeadProj {
        q: MatI8,
        k: MatI8,
        v: MatI8,
        per_seq: Vec<Activity>,
        shared: Activity,
    }
    let mut head_slots: Vec<Option<HeadProj>> = (0..dims.h).map(|_| None).collect();
    {
        let (x_all, lens, w, wt) = (&x_all, &lens[..], &weights, &weights_t);
        let tasks: Vec<Task> = head_slots
            .iter_mut()
            .enumerate()
            .map(|(h, slot)| {
                Box::new(move || {
                    let mut eng = TileEngine::new(cfg);
                    let mut per_seq = vec![Activity::default(); n];
                    let mut shared = Activity::default();
                    let hw = &w.heads[h];
                    let (wqt, wkt, wvt) = &wt.heads[h];
                    let q = eng
                        .linear_pret_multi(x_all, lens, wqt, &hw.bq, rq.q, &mut per_seq, &mut shared);
                    let k = eng
                        .linear_pret_multi(x_all, lens, wkt, &hw.bk, rq.k, &mut per_seq, &mut shared);
                    let v = eng
                        .linear_pret_multi(x_all, lens, wvt, &hw.bv, rq.v, &mut per_seq, &mut shared);
                    *slot = Some(HeadProj { q, k, v, per_seq, shared });
                }) as Task
            })
            .collect();
        WorkerPool::global().run(tasks);
    }
    let heads: Vec<HeadProj> =
        head_slots.into_iter().map(|s| s.expect("head projection task completed")).collect();
    let mut per_seq = vec![Activity::default(); n];
    let mut shared = Activity::default();
    for hp in &heads {
        for (acc, a) in per_seq.iter_mut().zip(&hp.per_seq) {
            acc.add(a);
        }
        shared.add(&hp.shared);
    }

    // ---- Stage 2: per-session causal cores, fanned out --------------
    // Each task owns one session's engine exclusively; the row slices
    // are cut task-locally so the copies parallelize too. The slice
    // copies are O(Sᵢ·P) per head — ~E× smaller than the O(Sᵢ·E·P)
    // GEMM that produced the rows — the price of keeping the bit-exact
    // causal core's whole-matrix API instead of threading row ranges
    // through it.
    struct SessionOut {
        concat: MatI8,
        attn: Vec<MatU8>,
    }
    let mut session_slots: Vec<Option<SessionOut>> = (0..n).map(|_| None).collect();
    {
        let heads = &heads;
        let tasks: Vec<Task> = engines
            .iter_mut()
            .zip(session_slots.iter_mut())
            .enumerate()
            .map(|(i, (eng, slot))| {
                let (off, len) = (offsets[i], lens[i]);
                Box::new(move || {
                    eng.engine.reset_activity();
                    let qkv: Vec<(MatI8, MatI8, MatI8)> = heads
                        .iter()
                        .map(|hp| {
                            (
                                hp.q.block_padded(off, 0, len, dims.p),
                                hp.k.block_padded(off, 0, len, dims.p),
                                hp.v.block_padded(off, 0, len, dims.p),
                            )
                        })
                        .collect();
                    let (concat, attn) = eng.prefill_from_projected(&qkv);
                    *slot = Some(SessionOut { concat, attn });
                }) as Task
            })
            .collect();
        WorkerPool::global().run(tasks);
    }
    let session_outs: Vec<SessionOut> =
        session_slots.into_iter().map(|s| s.expect("session causal task completed")).collect();

    // ---- Stage 3: the one fused output projection -------------------
    let mut concat_all = MatI8::zeros(m_total, dims.h * dims.p);
    for (s, &off) in session_outs.iter().zip(&offsets) {
        for r in 0..s.concat.rows() {
            concat_all.row_mut(off + r).copy_from_slice(s.concat.row(r));
        }
    }
    let mut eng_o = TileEngine::new(cfg);
    let mut per_seq_o = vec![Activity::default(); n];
    let out_all = eng_o.linear_pret_multi(
        &concat_all,
        &lens,
        &weights_t.wot,
        &weights.bo,
        rq.o,
        &mut per_seq_o,
        &mut shared,
    );
    for (acc, a) in per_seq.iter_mut().zip(&per_seq_o) {
        acc.add(a);
    }

    // Attribute each session's projection shares onto its engine (the
    // causal-core activity is already there) and assemble the outputs.
    let mut outputs = Vec::with_capacity(n);
    for (i, (eng, sess)) in engines.iter_mut().zip(session_outs).enumerate() {
        eng.engine.activity.add(&per_seq[i]);
        outputs.push(AttentionOutput {
            out: out_all.block_padded(offsets[i], 0, lens[i], dims.e),
            attn: sess.attn,
        });
    }
    FusedPrefillResult { outputs, shared }
}

/// Reusable scratch + entry point of the fused decode tick
/// (§Step-batching): N sessions' pending token rows, all against the
/// **same** [`PackedWeights`], stacked into one N-row matrix and run
/// through **one** blocked GEMM per projection weight
/// ([`TileEngine::linear_rows_pret_multi`]) instead of N separate
/// R=1 row passes — the decode-side completion of the fused-prefill
/// rework (N concurrent sessions used to re-stream all 3·H + 1 weight
/// matrices every tick).
///
/// # Dataflow per tick
///
/// 1. Stack the members' input rows into `x_all` (M×E, M = Σ lens).
/// 2. **Stage 1** — per head, one task on the [`WorkerPool`]: three
///    fused ragged GEMMs (Wq/Wk/Wv) producing the stacked M×P Q/K/V.
/// 3. **Stage 2** — per session, one task: the O(S) cache-attention
///    tail(s) on the session's own engine
///    ([`DecodeEngine::step_from_projected`] for R=1 members,
///    [`DecodeEngine::prefill_chunk_from_projected`] per row for
///    R=chunk members): cache append, logit row, streaming softmax,
///    A·V.
/// 4. **Stage 3** — gather the concat rows (M×H·P) and run the one
///    fused output projection (Wo), scattering each member's output
///    rows into `out_all`.
///
/// # Mixed-R members (§Chunked-prefill)
///
/// A member's input slice may carry `r` stacked rows (`r·E` bytes, `r
/// ≥ 1`): an **R=r prefill chunk** advancing a partial prefill sits in
/// the same stack as the R=1 decode steps, sharing their weight
/// streams — the tick has no prefill/decode split, only members
/// advancing by different row counts. [`FusedStepBatch::out_row`]
/// returns a member's **last** output row (the only row a generation
/// loop consumes: the chunk that completes a prefill seeds the first
/// feedback token exactly as a monolithic prefill's last output row
/// does).
///
/// Everything is **bit-identical** to N independent
/// [`DecodeEngine::step_into`] / [`DecodeEngine::prefill_chunk`] calls
/// — outputs, attention rows, cache bytes, and every subsequent step —
/// pinned by `tests/step_fused.rs` and `tests/prefill_chunked.rs`
/// across ragged cache fills and all dispatch paths.
///
/// Accounting mirrors the fused-prefill split: each engine's activity
/// is reset and left holding exactly its session's share (its tails
/// plus its R=lens[i] slice of every projection pass, streams
/// excluded); the 3·H + 1 weight streams are charged **once per
/// tick** into [`FusedStepBatch::shared`], however many prompt rows
/// rode along.
///
/// §Perf: every buffer lives here and is grown on first use, and the
/// pool fan-outs ride the allocation-free [`IndexedScope`] path — a
/// steady-state tick performs **zero heap allocations**
/// (`tests/decode_alloc.rs`), so the coordinator keeps one of these
/// per worker and ticks at line rate.
pub struct FusedStepBatch {
    /// M×E stacked input rows (M = Σ lens).
    x_all: MatI8,
    /// Per-member row counts (1 for a decode step, chunk_rows for a
    /// prefill chunk) and row offsets into the M-row stack.
    lens: Vec<usize>,
    base: Vec<usize>,
    /// Per head: the batch-wide stacked M×P Q/K/V of stage 1.
    qkv: Vec<(MatI8, MatI8, MatI8)>,
    /// Per head: the task-private engine running its three GEMMs.
    head_engines: Vec<TileEngine>,
    /// Per head: (per-session shares, stream-only share) of stage 1.
    head_acc: Vec<(Vec<Activity>, Activity)>,
    /// M×(H·P) gathered concat rows; M×E fused output.
    concat_all: MatI8,
    out_all: MatI8,
    /// Merged per-session projection shares (stages 1 + 3).
    per_seq: Vec<Activity>,
    shared: Activity,
    /// Engine of the fused output projection (created on first tick —
    /// the ItaConfig arrives with the engines).
    out_engine: Option<TileEngine>,
    /// Reusable allocation-free fan-out handle.
    scope: IndexedScope,
}

impl FusedStepBatch {
    pub fn new() -> Self {
        Self {
            x_all: MatI8::zeros(0, 0),
            lens: Vec::new(),
            base: Vec::new(),
            qkv: Vec::new(),
            head_engines: Vec::new(),
            head_acc: Vec::new(),
            concat_all: MatI8::zeros(0, 0),
            out_all: MatI8::zeros(0, 0),
            per_seq: Vec::new(),
            shared: Activity::default(),
            out_engine: None,
            scope: IndexedScope::new(),
        }
    }

    /// Run one fused tick: member `i` consumes input slice `rows[i]` —
    /// `lens[i]·E` bytes, where `lens[i] = 1` is a decode step and
    /// `lens[i] > 1` a prefill chunk (§Chunked-prefill — the slice
    /// length is the only signal; the tick needs no semantic split).
    /// Afterwards [`FusedStepBatch::out_row`]`(i)` holds its last
    /// output row, [`FusedStepBatch::shared`] the once-per-tick
    /// weight-stream activity, and each engine's activity its own
    /// share (see the type docs).
    ///
    /// Fault containment: a panic inside one session's stage-2 attend
    /// tail (or chunk) is caught and reported in
    /// [`TickReport::poisoned`] instead of unwinding the tick — every
    /// *other* session's tails still run on its own engine against the
    /// same stage-1 projections, and the stage-3 output projection is
    /// row-independent, so survivor outputs are bit-identical to a
    /// fault-free tick (pinned by `tests/chaos.rs`). Panics outside
    /// stage 2 (shared projection GEMMs — nothing session-specific can
    /// fail there) still unwind.
    pub fn tick(&mut self, engines: &mut [&mut DecodeEngine], rows: &[&[i8]]) -> TickReport {
        let n = engines.len();
        assert_eq!(n, rows.len(), "one input slice per session");
        assert!(n >= 1, "fused step needs at least one session");
        let dims = engines[0].dims;
        let cfg = engines[0].engine.cfg;
        let rq = engines[0].requants;
        let weights = engines[0].weights.clone();
        let weights_t = engines[0].weights_t.clone();
        self.lens.clear();
        self.base.clear();
        let mut m_total = 0usize;
        for (i, (e, row)) in engines.iter().zip(rows).enumerate() {
            assert!(
                Arc::ptr_eq(&e.weights, &weights) && Arc::ptr_eq(&e.weights_t, &weights_t),
                "fused step requires every session to share one packed model (session {i})"
            );
            // One tile geometry for the per-session shares — a session
            // with a different ItaConfig would be silently mis-charged.
            assert!(
                e.engine.cfg == cfg,
                "fused step requires every session to share one ItaConfig (session {i})"
            );
            assert!(
                !row.is_empty() && row.len() % dims.e == 0,
                "input slice must be a nonzero multiple of E rows (session {i})"
            );
            let r = row.len() / dims.e;
            assert!(e.len() + r <= e.capacity(), "input beyond KV capacity (session {i})");
            self.lens.push(r);
            self.base.push(m_total);
            m_total += r;
        }

        // ---- Block reservation: fallible, serial, before compute ----
        // Every member's next lens[i] positions are reserved on the
        // (possibly shared, bounded) arena *up front* — including any
        // copy-on-write forks of shared prefix blocks — so pool
        // exhaustion is a per-session report instead of a mid-tail
        // panic — for a chunk this is the per-chunk (not whole-prompt)
        // reservation of the chunked-prefill memory story. Serial in
        // index order: deterministic victims, no free-list races. A
        // PANIC inside one member's reservation (e.g. an injected
        // `kv.cow.fork` fault) is caught and quarantined to that
        // member exactly like a stage-2 tail panic: its tail is
        // skipped and it lands in [`TickReport::poisoned`], while
        // exhaustion stays a recoverable [`TickReport::exhausted`].
        // The fault-free case pushes nothing (an empty Vec never
        // allocates) and `catch_unwind` costs nothing on the
        // non-panicking path, preserving the tick's zero-allocation
        // contract.
        let mut exhausted: Vec<usize> = Vec::new();
        let mut reserve_poisoned: Vec<usize> = Vec::new();
        for (i, e) in engines.iter_mut().enumerate() {
            let new_len = e.len() + self.lens[i];
            match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| e.reserve_for(new_len)))
            {
                Ok(Ok(())) => {}
                Ok(Err(_)) => exhausted.push(i),
                Err(_) => reserve_poisoned.push(i),
            }
        }

        // Scratch sizing: allocates only while m / dims still grow —
        // a steady-state tick reuses everything below.
        self.x_all.reset_for_overwrite(m_total, dims.e);
        for (i, row) in rows.iter().enumerate() {
            for j in 0..self.lens[i] {
                self.x_all
                    .row_mut(self.base[i] + j)
                    .copy_from_slice(&row[j * dims.e..(j + 1) * dims.e]);
            }
        }
        if self.head_engines.first().map(|e| e.cfg != cfg).unwrap_or(false)
            || self.out_engine.as_ref().map(|e| e.cfg != cfg).unwrap_or(false)
        {
            // Scratch reused across models with different tile
            // geometry (tests; multi-model hosts): rebuild engines.
            self.head_engines.clear();
            self.out_engine = None;
        }
        while self.qkv.len() < dims.h {
            self.qkv.push((MatI8::zeros(0, 0), MatI8::zeros(0, 0), MatI8::zeros(0, 0)));
        }
        while self.head_engines.len() < dims.h {
            self.head_engines.push(TileEngine::new(cfg));
        }
        while self.head_acc.len() < dims.h {
            self.head_acc.push((Vec::new(), Activity::default()));
        }
        for (per_seq, stream) in &mut self.head_acc[..dims.h] {
            per_seq.clear();
            per_seq.resize(n, Activity::default());
            *stream = Activity::default();
        }
        self.shared = Activity::default();

        // ---- Stage 1: one fused ragged GEMM per projection weight ---
        // One index per head; its three weight matrices are streamed
        // back to back on its persistent engine. Indexed fan-out:
        // executors claim head indices, DisjointSlots turns claim
        // uniqueness into disjoint &mut access (no boxed tasks — the
        // zero-alloc contract). The lens-aware pass charges each
        // member its own R=lens[i] tile pass, so a chunk's projection
        // share is exactly what its standalone chunk would record.
        {
            let qkv = DisjointSlots::new(&mut self.qkv[..dims.h]);
            let engs = DisjointSlots::new(&mut self.head_engines[..dims.h]);
            let accs = DisjointSlots::new(&mut self.head_acc[..dims.h]);
            let x_all = &self.x_all;
            let lens = &self.lens[..];
            let (w, wt) = (&weights, &weights_t);
            WorkerPool::global().run_indexed(&self.scope, dims.h, &|h| {
                // SAFETY: run_indexed hands index h to exactly one
                // executor; each slot is touched only at its own h.
                let (q, k, v) = unsafe { qkv.slot(h) };
                let eng = unsafe { engs.slot(h) };
                let (per_seq, stream) = unsafe { accs.slot(h) };
                eng.reset_activity();
                let hw = &w.heads[h];
                let (wqt, wkt, wvt) = &wt.heads[h];
                eng.linear_lens_pret_multi(x_all, lens, wqt, &hw.bq, rq.q, per_seq, stream, q);
                eng.linear_lens_pret_multi(x_all, lens, wkt, &hw.bk, rq.k, per_seq, stream, k);
                eng.linear_lens_pret_multi(x_all, lens, wvt, &hw.bv, rq.v, per_seq, stream, v);
            });
        }
        self.per_seq.clear();
        self.per_seq.resize(n, Activity::default());
        for (per_seq_h, stream_h) in &self.head_acc[..dims.h] {
            for (acc, a) in self.per_seq.iter_mut().zip(per_seq_h) {
                acc.add(a);
            }
            self.shared.add(stream_h);
        }

        // ---- Stage 2: per-session O(S) cache-attention tails --------
        // One index per session; each executor owns that session's
        // engine exclusively and reads the shared Q/K/V stacks. A
        // panicking tail is contained to its own index: the try_ scope
        // still completes every other session, and the failed indices
        // come back for the caller to quarantine.
        let failure: Option<ScopeFailure> = {
            let qkv = &self.qkv[..dims.h];
            let engs = DisjointSlots::new(engines);
            let exhausted = &exhausted;
            let reserve_poisoned = &reserve_poisoned;
            let lens = &self.lens[..];
            let base = &self.base[..];
            WorkerPool::global()
                .try_run_indexed(&self.scope, n, &|i| {
                    // An exhausted session's tail is skipped outright:
                    // its caches are untouched, its input rows stay
                    // unconsumed (the router re-ticks it after
                    // preemption frees blocks), and its out_row slot
                    // holds garbage nobody reads. A reserve-poisoned
                    // session is skipped too — its owner quarantines
                    // it.
                    if exhausted.binary_search(&i).is_ok()
                        || reserve_poisoned.binary_search(&i).is_ok()
                    {
                        return;
                    }
                    // SAFETY: one executor per session index.
                    let eng = unsafe { engs.slot(i) };
                    eng.engine.reset_activity();
                    if lens[i] == 1 {
                        eng.step_from_projected(qkv, base[i]);
                    } else {
                        eng.prefill_chunk_from_projected(qkv, base[i], lens[i]);
                    }
                })
                .err()
        };
        self.concat_all.reset_for_overwrite(m_total, dims.h * dims.p);
        let poisoned: &[usize] = failure.as_ref().map(|f| f.indices.as_slice()).unwrap_or(&[]);
        for (i, eng) in engines.iter().enumerate() {
            let (b, r) = (self.base[i], self.lens[i]);
            if r == 1 {
                // A poisoned step's concat scratch holds stale bytes —
                // its stage-3 row computes garbage that nobody reads;
                // the GEMM is row-independent, so survivor rows are
                // unaffected.
                self.concat_all.row_mut(b).copy_from_slice(eng.last_concat());
            } else if exhausted.binary_search(&i).is_err()
                && poisoned.binary_search(&i).is_err()
                && reserve_poisoned.binary_search(&i).is_err()
            {
                // Chunk members: gather the chunk's concat rows. A
                // skipped (exhausted/poisoned) chunk's scratch may be
                // stale-shaped, so leave its stage-3 rows as the
                // garbage nobody reads.
                for j in 0..r {
                    self.concat_all.row_mut(b + j).copy_from_slice(eng.chunk_concat.row(j));
                }
            }
        }

        // ---- Stage 3: the one fused output projection ---------------
        let out_engine = self.out_engine.get_or_insert_with(|| TileEngine::new(cfg));
        out_engine.reset_activity();
        out_engine.linear_lens_pret_multi(
            &self.concat_all,
            &self.lens,
            &weights_t.wot,
            &weights.bo,
            rq.o,
            &mut self.per_seq,
            &mut self.shared,
            &mut self.out_all,
        );

        // Attribute each session's projection shares onto its engine
        // (the tail activity is already there). Poisoned engines are
        // charged too — their owner discards them anyway.
        for (i, eng) in engines.iter_mut().enumerate() {
            eng.engine.activity.add(&self.per_seq[i]);
        }
        let mut poisoned = failure.map(|f| f.indices).unwrap_or_default();
        if !reserve_poisoned.is_empty() {
            // Reservation-phase panics join the stage-2 ones (sorted
            // merge — callers binary_search this list). Fault path
            // only: the allocation is fine here.
            poisoned.extend_from_slice(&reserve_poisoned);
            poisoned.sort_unstable();
        }
        TickReport { poisoned, exhausted }
    }

    /// Member `i`'s **last** output row (length E) of the most recent
    /// tick — the row a generation loop consumes. For an R=1 decode
    /// step that is its only output row; for an R=r prefill chunk it
    /// is the chunk's final row (the one that, on the prompt's last
    /// chunk, seeds the first feedback token bit-identically to a
    /// monolithic prefill's last output row).
    pub fn out_row(&self, i: usize) -> &[i8] {
        self.out_all.row(self.base[i] + self.lens[i] - 1)
    }

    /// Member `i`'s full output block (lens[i]×E) of the most recent
    /// tick (allocates — a test/debug accessor, not a serving path).
    pub fn out_block(&self, i: usize) -> MatI8 {
        self.out_all.block_padded(self.base[i], 0, self.lens[i], self.out_all.cols())
    }

    /// The batch-shared activity of the most recent tick: the
    /// once-per-tick projection weight streams (3·H + 1 matrices,
    /// `weight_buf_writes` only) — the decode mirror of
    /// [`FusedPrefillResult::shared`].
    pub fn shared(&self) -> &Activity {
        &self.shared
    }
}

impl Default for FusedStepBatch {
    fn default() -> Self {
        Self::new()
    }
}

/// Fault report of one [`FusedStepBatch::tick`]. The fault-free case
/// carries empty (never-allocated) `Vec`s, preserving the tick's
/// zero-allocation contract.
#[must_use = "a tick may have poisoned/exhausted sessions; check ok()"]
#[derive(Debug, Default)]
pub struct TickReport {
    /// Batch indices whose stage-2 attend tail panicked — or whose
    /// pre-tick block reservation panicked (e.g. an injected
    /// `kv.cow.fork` fault mid-fork). Those sessions' engines are left
    /// with partially-advanced KV caches (the tail pushes K/V *before*
    /// computing — see [`attend_tail`]) and their `out_row` slots hold
    /// garbage; the caller must discard the engines. All other indices
    /// are untouched by the failure and bit-identical to a fault-free
    /// tick. Sorted (callers binary-search it).
    pub poisoned: Vec<usize>,
    /// Batch indices whose pre-tick block reservation hit
    /// [`BlockPoolExhausted`] (sorted — built in index order). Unlike
    /// poisoning this is **recoverable**: the session's caches are
    /// untouched, its token row was not consumed, and its engine stays
    /// healthy — the caller frees memory (preemption) and re-ticks it.
    /// Its `out_row` slot holds garbage for this tick only.
    pub exhausted: Vec<usize>,
}

impl TickReport {
    /// True when every session in the tick completed.
    pub fn ok(&self) -> bool {
        self.poisoned.is_empty() && self.exhausted.is_empty()
    }
}

/// Result of one [`fused_step`] convenience call.
pub struct FusedStepResult {
    /// Per-session output rows (length E each), in input order —
    /// bit-identical to what each session's independent
    /// [`DecodeEngine::step`] would have returned.
    pub outputs: Vec<Vec<i8>>,
    /// The once-per-tick weight-stream activity (see
    /// [`FusedStepBatch::shared`]).
    pub shared: Activity,
}

/// Convenience wrapper mirroring [`fused_prefill`]: one fused decode
/// tick through a throwaway [`FusedStepBatch`]. Serving paths that
/// tick repeatedly should hold a `FusedStepBatch` instead (its warm
/// scratch makes steady-state ticks allocation-free).
pub fn fused_step(engines: &mut [&mut DecodeEngine], rows: &[&[i8]]) -> FusedStepResult {
    let mut batch = FusedStepBatch::new();
    let report = batch.tick(engines, rows);
    assert!(
        report.ok(),
        "fused_step tick faulted (poisoned {:?}, exhausted {:?})",
        report.poisoned,
        report.exhausted
    );
    FusedStepResult {
        outputs: (0..rows.len()).map(|i| batch.out_row(i).to_vec()).collect(),
        shared: batch.shared,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{gen_input, run_attention_causal};
    use crate::util::rng::SplitMix64;

    fn dims() -> ModelDims {
        ModelDims { s: 24, e: 16, p: 8, h: 2 }
    }

    #[test]
    fn kv_cache_push_and_layouts() {
        let mut c = KvCache::new(4, 3);
        assert!(c.is_empty());
        c.push(&[1, 2, 3], &[4, 5, 6]);
        c.push(&[7, 8, 9], &[10, 11, 12]);
        assert_eq!(c.len(), 2);
        assert_eq!(c.k_row(0), &[1, 2, 3]);
        assert_eq!(c.k_row(1), &[7, 8, 9]);
        // Vᵀ pack: block column i holds value row i.
        assert_eq!(c.v_col(0), vec![4, 5, 6]);
        assert_eq!(c.v_col(1), vec![10, 11, 12]);
    }

    #[test]
    fn kv_cache_pages_across_block_boundaries() {
        // A tiny shared arena (block_size 2) forces the table to span
        // blocks; rows and value columns must read back exactly across
        // the boundary, and blocks must flow through the arena.
        let arena = BlockArena::new(2, 3, 4);
        let mut c = KvCache::with_arena(arena.clone(), 5);
        assert_eq!(c.block_size(), 2);
        for i in 0..5i8 {
            c.push(&[i, i + 10, i + 20], &[i + 30, i + 40, i + 50]);
        }
        assert_eq!(c.blocks().len(), 3, "5 positions at block_size 2 -> 3 blocks");
        assert_eq!(arena.blocks_in_use(), 3);
        for i in 0..5i8 {
            assert_eq!(c.k_row(i as usize), &[i, i + 10, i + 20], "key row {i}");
            assert_eq!(c.v_col(i as usize), vec![i + 30, i + 40, i + 50], "value row {i}");
        }
        c.release_blocks();
        assert!(c.is_empty());
        assert_eq!(arena.blocks_in_use(), 0, "release returns every block");
        assert_eq!(arena.blocks_free(), 4);
    }

    #[test]
    fn kv_cache_truncate_preserves_prefix() {
        let mut c = KvCache::new(4, 2);
        c.push(&[1, 2], &[3, 4]);
        c.push(&[5, 6], &[7, 8]);
        c.truncate(1);
        assert_eq!(c.len(), 1);
        assert_eq!(c.k_row(0), &[1, 2]);
        c.push(&[9, 9], &[9, 9]); // overwrites position 1
        assert_eq!(c.len(), 2);
        assert_eq!(c.k_row(1), &[9, 9]);
    }

    #[test]
    fn kv_cache_truncate_keeps_blocks_reserved() {
        // Truncate is arena-silent: the rolled-back blocks stay this
        // cache's reserved capacity (replay never touches the pool).
        let arena = BlockArena::new(2, 2, 3);
        let mut c = KvCache::with_arena(arena.clone(), 6);
        for i in 0..5i8 {
            c.push(&[i, i], &[i, i]);
        }
        assert_eq!(arena.blocks_in_use(), 3);
        c.truncate(1);
        assert_eq!(arena.blocks_in_use(), 3, "truncate returns nothing");
        for i in 0..4i8 {
            c.push(&[9 + i, 9], &[9, 9 + i]);
        }
        assert_eq!(arena.blocks_in_use(), 3, "replay re-used the retained blocks");
        assert_eq!(c.k_row(0), &[0, 0]);
        assert_eq!(c.k_row(2), &[10, 9]);
    }

    #[test]
    fn kv_cache_reserve_failure_rolls_back_and_recovers() {
        // Two caches on a 3-block arena (block_size 2): the second
        // cache's over-reserve fails WITHOUT stranding the blocks it
        // drew, and succeeds once the first cache releases.
        let arena = BlockArena::new(2, 2, 3);
        let mut a = KvCache::with_arena(arena.clone(), 6);
        let mut b = KvCache::with_arena(arena.clone(), 6);
        a.reserve(4).unwrap(); // 2 blocks
        let err = b.reserve(4).unwrap_err(); // needs 2, only 1 free
        assert_eq!(err.total_blocks, 3);
        assert_eq!(arena.blocks_in_use(), 2, "failed reserve returned its draw");
        b.reserve(2).unwrap(); // 1 block still fits
        assert_eq!(arena.blocks_in_use(), 3);
        a.release_blocks();
        b.reserve(6).unwrap();
        assert_eq!(arena.blocks_in_use(), 3);
        drop(b);
        assert_eq!(arena.blocks_free(), 3, "drop reclaims (no leaks)");
    }

    #[test]
    #[should_panic(expected = "KV cache full")]
    fn kv_cache_rejects_overflow() {
        let mut c = KvCache::new(1, 2);
        c.push(&[1, 2], &[3, 4]);
        c.push(&[5, 6], &[7, 8]);
    }

    #[test]
    fn kv_cache_adopt_shares_blocks_and_forks_on_divergent_append() {
        // Donor caches 3 rows (block_size 2: one full + one partial
        // block). Adoption bumps refcounts without touching the pool;
        // the adopter's first append lands in the shared partial tail
        // block and must CoW-fork it, leaving the donor's bytes
        // untouched and the shared full block still shared.
        let arena = BlockArena::new(2, 2, 4);
        let mut donor = KvCache::with_arena(arena.clone(), 6);
        for i in 0..3i8 {
            donor.push(&[i, i + 10], &[i + 20, i + 30]);
        }
        assert_eq!(arena.blocks_in_use(), 2);

        let mut adopter = KvCache::with_arena(arena.clone(), 6);
        adopter.adopt(&donor.share_blocks(3), 3);
        assert_eq!(adopter.len(), 3);
        assert_eq!(arena.blocks_in_use(), 2, "adoption is refcount-only");
        assert!(donor.blocks()[0].is_shared() && donor.blocks()[1].is_shared());
        for i in 0..3 {
            assert_eq!(adopter.k_row(i), donor.k_row(i), "adopted key row {i}");
            assert_eq!(adopter.v_col(i), donor.v_col(i), "adopted value row {i}");
        }

        // Divergent append: position 3 lives in the shared tail block.
        adopter.reserve(4).unwrap();
        assert_eq!(arena.blocks_in_use(), 3, "the fork drew one fresh block");
        assert_eq!(arena.cow_forks(), 1);
        adopter.push(&[77, 78], &[79, 80]);
        assert_eq!(adopter.k_row(2), donor.k_row(2), "retained row copied by the fork");
        assert_eq!(adopter.k_row(3), &[77, 78]);
        assert!(!donor.blocks()[1].is_shared(), "fork released the donor's tail");
        assert!(donor.blocks()[0].is_shared(), "full prefix block still shared");
        // The donor's own append path is unaffected.
        donor.push(&[1, 2], &[3, 4]);
        assert_eq!(donor.k_row(3), &[1, 2]);
        assert_eq!(adopter.k_row(3), &[77, 78], "divergence stays private");

        donor.release_blocks();
        adopter.release_blocks();
        assert_eq!(arena.blocks_in_use(), 0, "all physical blocks returned");
        assert_eq!(arena.blocks_free(), 4);
    }

    #[test]
    fn kv_cache_exact_block_adoption_forks_nothing_until_shared_tail() {
        // A block-aligned prefix (4 rows, block_size 2): the adopter's
        // appends start a FRESH block, so no fork happens at all.
        let arena = BlockArena::new(2, 2, 4);
        let mut donor = KvCache::with_arena(arena.clone(), 8);
        for i in 0..4i8 {
            donor.push(&[i, i], &[i, i]);
        }
        let mut adopter = KvCache::with_arena(arena.clone(), 8);
        adopter.adopt(&donor.share_blocks(4), 4);
        adopter.reserve(5).unwrap();
        adopter.push(&[9, 9], &[9, 9]);
        assert_eq!(arena.cow_forks(), 0, "aligned divergence needs no fork");
        assert_eq!(arena.blocks_in_use(), 3);
        drop(donor);
        drop(adopter);
        assert_eq!(arena.blocks_in_use(), 0);
    }

    #[test]
    fn adopted_prefix_continuation_matches_cold_prefill() {
        // The tentpole bit-exactness property at engine level: adopt a
        // donor's prefix blocks (mid-block boundary), chunk-prefill the
        // divergent suffix, then decode — everything must equal a cold
        // engine prefilling the full prompt, and the donor must stay
        // bit-exact after the adopter's CoW fork.
        let d = dims();
        let cfg = ItaConfig::tiny();
        let packed = PackedWeights::shared(d, 5);
        let arena = BlockArena::new(4, d.p, 64);
        let mk = |arena: &Arc<BlockArena>| {
            DecodeEngine::from_shared_arena(
                cfg,
                d,
                packed.weights.clone(),
                packed.weights_t.clone(),
                packed.requants,
                arena.clone(),
            )
        };
        let x = gen_input(6, &d);
        let prompt_rows = 10usize;
        let shared_rows = 6usize; // 6 % 4 != 0: mid-block divergence

        let mut donor = mk(&arena);
        donor.prefill(&x.block_padded(0, 0, shared_rows, d.e));
        let mut cold = mk(&arena);
        let want = cold.prefill(&x.block_padded(0, 0, prompt_rows, d.e));

        let mut adopter = mk(&arena);
        adopter.adopt_prefix(&donor.share_prefix(shared_rows), shared_rows);
        assert_eq!(adopter.len(), shared_rows);
        let suffix = x.block_padded(shared_rows, 0, prompt_rows - shared_rows, d.e);
        adopter.reserve_for(prompt_rows).unwrap();
        assert_eq!(arena.cow_forks(), d.h, "one tail fork per head");
        let got = adopter.prefill_chunk(&suffix);
        for (j, r) in (shared_rows..prompt_rows).enumerate() {
            assert_eq!(got.row(j), want.out.row(r), "suffix output row {r}");
        }
        // Decode steps from the adopted engine equal the cold engine's.
        for r in prompt_rows..d.s {
            assert_eq!(adopter.step(x.row(r)), cold.step(x.row(r)), "step at row {r}");
        }
        // The donor was never perturbed: its own continuation matches a
        // fresh replay of the same sequence.
        let mut donor_oracle = mk(&arena);
        donor_oracle.prefill(&x.block_padded(0, 0, shared_rows, d.e));
        let y = gen_input(7, &d);
        for r in 0..4 {
            assert_eq!(donor.step(y.row(r)), donor_oracle.step(y.row(r)), "donor step {r}");
        }

        drop(donor);
        drop(donor_oracle);
        drop(adopter);
        drop(cold);
        assert_eq!(arena.blocks_in_use(), 0, "refcounts balanced at quiesce");
    }

    #[test]
    fn zero_row_adoption_is_a_cold_start() {
        // prefix length 0: adopt nothing, everything prefills locally.
        let d = dims();
        let mut a = DecodeEngine::new(ItaConfig::tiny(), d, 5);
        let b = DecodeEngine::new(ItaConfig::tiny(), d, 5);
        a.adopt_prefix(&b.share_prefix(0), 0);
        assert!(a.is_empty());
        let x = gen_input(9, &d);
        let mut cold = DecodeEngine::new(ItaConfig::tiny(), d, 5);
        assert_eq!(a.prefill(&x).out, cold.prefill(&x).out);
    }

    #[test]
    fn prefill_matches_full_causal_oracle() {
        let d = dims();
        let mut de = DecodeEngine::new(ItaConfig::tiny(), d, 5);
        let x = gen_input(6, &d);
        let got = de.prefill(&x);
        let mut eng = TileEngine::new(ItaConfig::tiny());
        let want = run_attention_causal(&mut eng, &x, &de.weights, &de.requants);
        assert_eq!(got.out, want.out);
        assert_eq!(got.attn, want.attn);
        assert_eq!(de.len(), d.s);
        // Activity accounting identical too (same kernels, same order).
        assert_eq!(de.engine.activity, eng.activity);
    }

    #[test]
    fn steps_match_full_causal_rows() {
        // Prefill 10 rows, then step the rest one by one: each step's
        // output must equal the matching row of the full causal
        // recompute, and the attention rows must match the unmasked
        // prefix of the oracle's rows.
        let d = dims();
        let mut de = DecodeEngine::new(ItaConfig::tiny(), d, 7);
        let x = gen_input(8, &d);
        let p0 = 10;
        de.prefill(&x.block_padded(0, 0, p0, d.e));
        let mut eng = TileEngine::new(ItaConfig::tiny());
        let full = run_attention_causal(&mut eng, &x, &de.weights, &de.requants);
        let mut out = Vec::new();
        for r in p0..d.s {
            de.step_into(x.row(r), &mut out);
            assert_eq!(&out[..], full.out.row(r), "step at row {r}");
            for h in 0..d.h {
                let valid = r + 1;
                assert_eq!(de.last_attn_row(h), &full.attn[h].row(r)[..valid], "attn h={h} r={r}");
                assert!(full.attn[h].row(r)[valid..].iter().all(|&v| v == 0));
            }
        }
        assert_eq!(de.len(), d.s);
    }

    #[test]
    fn empty_prefill_then_steps_from_scratch() {
        // A session may start with no prompt at all: the first step's
        // row attends only to itself.
        let d = ModelDims { s: 6, e: 16, p: 8, h: 2 };
        let mut de = DecodeEngine::new(ItaConfig::tiny(), d, 11);
        let pre = de.prefill(&MatI8::zeros(0, d.e));
        assert_eq!(pre.out.shape(), (0, d.e));
        let x = gen_input(12, &d);
        let mut eng = TileEngine::new(ItaConfig::tiny());
        let full = run_attention_causal(&mut eng, &x, &de.weights, &de.requants);
        let mut out = Vec::new();
        for r in 0..d.s {
            de.step_into(x.row(r), &mut out);
            assert_eq!(&out[..], full.out.row(r), "row {r}");
        }
        // Row 0 attended only to itself with full mass.
        assert!(full.attn[0].get(0, 0) >= 255);
    }

    #[test]
    fn truncate_replay_is_deterministic() {
        let d = dims();
        let mut de = DecodeEngine::new(ItaConfig::tiny(), d, 13);
        let x = gen_input(14, &d);
        de.prefill(&x.block_padded(0, 0, 8, d.e));
        let first = de.step(x.row(8));
        // Roll back and replay the same token: bit-identical.
        de.truncate(8);
        let replay = de.step(x.row(8));
        assert_eq!(first, replay);
        // Reset + fresh prefill reproduces the same step too.
        de.reset();
        de.prefill(&x.block_padded(0, 0, 8, d.e));
        assert_eq!(de.step(x.row(8)), first);
    }

    #[test]
    #[should_panic(expected = "non-empty cache")]
    fn prefill_requires_empty_cache() {
        let d = dims();
        let mut de = DecodeEngine::new(ItaConfig::tiny(), d, 1);
        let x = gen_input(2, &d);
        de.prefill(&x.block_padded(0, 0, 2, d.e));
        de.prefill(&x.block_padded(0, 0, 2, d.e));
    }

    #[test]
    fn step_activity_is_o_of_s() {
        // Useful MACs per step: 3·E·P + 2·valid·P per head, plus the
        // H·P×E output projection — linear in the sequence length.
        let d = dims();
        let mut de = DecodeEngine::new(ItaConfig::tiny(), d, 3);
        let x = gen_input(4, &d);
        de.prefill(&x.block_padded(0, 0, 4, d.e));
        de.engine.reset_activity();
        let _ = de.step(x.row(4));
        let valid = 5;
        let per_head = 3 * d.e * d.p + 2 * valid * d.p;
        let want = (d.h * per_head + d.h * d.p * d.e) as u64;
        assert_eq!(de.engine.activity.macs, want);
        assert_eq!(de.engine.activity.divisions, d.h as u64);
        assert_eq!(de.engine.activity.softmax_elems, (2 * valid * d.h) as u64);
    }

    #[test]
    fn prefill_from_projected_matches_plain_prefill() {
        // Feeding prefill the pre-projected Q/K/V by hand must leave
        // caches, attention, and concatenated head outputs identical
        // to the self-projecting path.
        let d = dims();
        let mut plain = DecodeEngine::new(ItaConfig::tiny(), d, 31);
        let mut proj = DecodeEngine::new(ItaConfig::tiny(), d, 31);
        let x = gen_input(32, &d).block_padded(0, 0, 9, d.e);
        let want = plain.prefill(&x);

        let rq = proj.requants;
        let mut eng = TileEngine::new(ItaConfig::tiny());
        let qkv: Vec<(MatI8, MatI8, MatI8)> = proj
            .weights
            .heads
            .iter()
            .zip(&proj.weights_t.heads)
            .map(|(hw, (wqt, wkt, wvt))| {
                (
                    eng.linear_pret(&x, wqt, &hw.bq, rq.q),
                    eng.linear_pret(&x, wkt, &hw.bk, rq.k),
                    eng.linear_pret(&x, wvt, &hw.bv, rq.v),
                )
            })
            .collect();
        let (concat, attn) = proj.prefill_from_projected(&qkv);
        assert_eq!(attn, want.attn);
        // Output projection of the concat equals the plain output.
        let got = eng.linear_pret(&concat, &proj.weights_t.wot, &proj.weights.bo, rq.o);
        assert_eq!(got, want.out);
        // Caches identical: the next step from both engines agrees.
        assert_eq!(proj.len(), plain.len());
        let row = gen_input(33, &d);
        assert_eq!(proj.step(row.row(0)), plain.step(row.row(0)));
    }

    #[test]
    fn fused_prefill_bit_identical_to_independent_prefills() {
        // Three sessions, ragged lengths (one empty): fused outputs,
        // attention rows, cache fills, and the first post-prefill step
        // all equal the independent per-session path.
        let d = dims();
        let lens = [5usize, 0, 11];
        let mut fused: Vec<DecodeEngine> =
            (0..3).map(|_| DecodeEngine::new(ItaConfig::tiny(), d, 51)).collect();
        let mut indep: Vec<DecodeEngine> =
            (0..3).map(|_| DecodeEngine::new(ItaConfig::tiny(), d, 51)).collect();
        let prompts: Vec<MatI8> = lens
            .iter()
            .enumerate()
            .map(|(i, &l)| gen_input(60 + i as u64, &d).block_padded(0, 0, l, d.e))
            .collect();

        let mut refs: Vec<&mut DecodeEngine> = fused.iter_mut().collect();
        let inputs: Vec<&MatI8> = prompts.iter().collect();
        let result = fused_prefill(&mut refs, &inputs);

        let x_next = gen_input(77, &d);
        for i in 0..3 {
            let want = indep[i].prefill(&prompts[i]);
            assert_eq!(result.outputs[i].out, want.out, "session {i} output");
            assert_eq!(result.outputs[i].attn, want.attn, "session {i} attention");
            assert_eq!(fused[i].len(), indep[i].len(), "session {i} cache fill");
            assert_eq!(
                fused[i].step(x_next.row(lens[i])),
                indep[i].step(x_next.row(lens[i])),
                "session {i} first step after prefill"
            );
        }
    }

    #[test]
    fn fused_prefill_streams_each_weight_once() {
        // The acceptance assertion: N fused sessions charge exactly
        // one projection weight stream per weight matrix (3·H + 1),
        // and each session's activity equals its independent prefill
        // minus exactly those streams — everything else bit-equal.
        use crate::ita::simulator::{activity_for_matmul, MatmulDims};
        let d = dims();
        let n = 3;
        let lens = [4usize, 9, 6];
        let cfg = ItaConfig::tiny();
        let mut fused: Vec<DecodeEngine> =
            (0..n).map(|_| DecodeEngine::new(cfg, d, 81)).collect();
        let prompts: Vec<MatI8> = lens
            .iter()
            .enumerate()
            .map(|(i, &l)| gen_input(90 + i as u64, &d).block_padded(0, 0, l, d.e))
            .collect();
        let mut refs: Vec<&mut DecodeEngine> = fused.iter_mut().collect();
        let inputs: Vec<&MatI8> = prompts.iter().collect();
        let result = fused_prefill(&mut refs, &inputs);

        // One stream per weight matrix: 3·H projections (E→P) + Wo
        // ((H·P)→E), independent of the session count.
        let proj = activity_for_matmul(&cfg, MatmulDims { r: 0, k: d.e, c: d.p }, 0);
        let out_proj =
            activity_for_matmul(&cfg, MatmulDims { r: 0, k: d.h * d.p, c: d.e }, 0);
        let streams_once =
            3 * d.h as u64 * proj.weight_buf_writes + out_proj.weight_buf_writes;
        assert_eq!(result.shared.weight_buf_writes, streams_once);
        assert_eq!(result.shared.macs, 0);
        assert_eq!(result.shared.cycles, 0);

        for i in 0..n {
            let mut indep = DecodeEngine::new(cfg, d, 81);
            indep.engine.reset_activity();
            indep.prefill(&prompts[i]);
            let mut fused_act = fused[i].engine.activity;
            fused_act.weight_buf_writes += streams_once;
            assert_eq!(
                fused_act, indep.engine.activity,
                "session {i}: fused share must be independent-minus-streams exactly"
            );
        }
    }

    #[test]
    fn fused_step_bit_identical_to_independent_steps() {
        // Three sessions at ragged cache fills (incl. one at S=1 right
        // after prefill and one empty): a fused tick's outputs,
        // attention rows, cache fills, and the NEXT independent step
        // all equal the per-session step_into path.
        let d = dims();
        let lens = [5usize, 1, 0];
        let mut fused: Vec<DecodeEngine> =
            (0..3).map(|_| DecodeEngine::new(ItaConfig::tiny(), d, 91)).collect();
        let mut indep: Vec<DecodeEngine> =
            (0..3).map(|_| DecodeEngine::new(ItaConfig::tiny(), d, 91)).collect();
        for (i, &l) in lens.iter().enumerate() {
            let prompt = gen_input(70 + i as u64, &d).block_padded(0, 0, l, d.e);
            fused[i].prefill(&prompt);
            indep[i].prefill(&prompt);
        }
        let x = gen_input(88, &d);
        let rows: Vec<&[i8]> = (0..3).map(|i| x.row(lens[i])).collect();

        let result = {
            let mut refs: Vec<&mut DecodeEngine> = fused.iter_mut().collect();
            fused_step(&mut refs, &rows)
        };

        let mut want = Vec::new();
        for i in 0..3 {
            indep[i].step_into(rows[i], &mut want);
            assert_eq!(result.outputs[i], want, "session {i} output");
            assert_eq!(fused[i].len(), indep[i].len(), "session {i} cache fill");
            for h in 0..d.h {
                assert_eq!(
                    fused[i].last_attn_row(h),
                    indep[i].last_attn_row(h),
                    "session {i} head {h} attention row"
                );
            }
            // The serving-visible cache proof: the following step
            // agrees bit for bit.
            let next = x.row(lens[i] + 1);
            assert_eq!(fused[i].step(next), indep[i].step(next), "session {i} next step");
        }
    }

    #[test]
    fn fused_step_batch_reuses_scratch_across_ticks() {
        // One FusedStepBatch driving several consecutive ticks (the
        // coordinator's steady state): every tick stays bit-identical
        // to the independent path as the caches grow.
        let d = dims();
        let n = 3;
        let mut fused: Vec<DecodeEngine> =
            (0..n).map(|_| DecodeEngine::new(ItaConfig::tiny(), d, 93)).collect();
        let mut indep: Vec<DecodeEngine> =
            (0..n).map(|_| DecodeEngine::new(ItaConfig::tiny(), d, 93)).collect();
        for (i, eng) in fused.iter_mut().chain(indep.iter_mut()).enumerate() {
            let prompt = gen_input(40 + (i % n) as u64, &d).block_padded(0, 0, 2 + i % n, d.e);
            eng.prefill(&prompt);
        }
        let mut batch = FusedStepBatch::new();
        let mut want = Vec::new();
        for t in 0..6u64 {
            let x = gen_input(200 + t, &d);
            let rows: Vec<&[i8]> = (0..n).map(|i| x.row(i)).collect();
            {
                let mut refs: Vec<&mut DecodeEngine> = fused.iter_mut().collect();
                assert!(batch.tick(&mut refs, &rows).ok(), "fault-free tick {t}");
            }
            for i in 0..n {
                indep[i].step_into(rows[i], &mut want);
                assert_eq!(batch.out_row(i), &want[..], "tick {t} session {i}");
            }
        }
    }

    #[test]
    fn fused_step_batch_survives_join_leave_churn() {
        // The continuous-batching router's contract: ONE long-lived
        // FusedStepBatch whose membership changes every tick (sessions
        // join mid-flight, leave mid-flight, rejoin, shrink to N=1) —
        // every surviving session stays bit-identical to its
        // independent step_into path at every tick.
        let d = dims();
        let n = 4;
        let mut fused: Vec<DecodeEngine> =
            (0..n).map(|_| DecodeEngine::new(ItaConfig::tiny(), d, 99)).collect();
        let mut indep: Vec<DecodeEngine> =
            (0..n).map(|_| DecodeEngine::new(ItaConfig::tiny(), d, 99)).collect();
        for (i, eng) in fused.iter_mut().chain(indep.iter_mut()).enumerate() {
            let prompt = gen_input(60 + (i % n) as u64, &d).block_padded(0, 0, 1 + i % n, d.e);
            eng.prefill(&prompt);
        }
        // Tick-by-tick membership: join (2), leave (1), rejoin after a
        // sat-out tick (1), shrink to a single survivor (3).
        let members: [&[usize]; 5] = [&[0, 1], &[0, 1, 2], &[0, 2], &[0, 1, 2, 3], &[3]];
        let mut batch = FusedStepBatch::new();
        let mut want = Vec::new();
        for (t, ms) in members.iter().enumerate() {
            let x = gen_input(300 + t as u64, &d);
            let rows: Vec<&[i8]> = ms.iter().map(|&i| x.row(i)).collect();
            {
                let mut refs: Vec<&mut DecodeEngine> = fused
                    .iter_mut()
                    .enumerate()
                    .filter(|(i, _)| ms.contains(i))
                    .map(|(_, e)| e)
                    .collect();
                assert!(batch.tick(&mut refs, &rows).ok(), "fault-free tick {t}");
            }
            for (k, &i) in ms.iter().enumerate() {
                indep[i].step_into(rows[k], &mut want);
                assert_eq!(batch.out_row(k), &want[..], "tick {t} session {i}");
                assert_eq!(fused[i].len(), indep[i].len(), "tick {t} session {i} fill");
            }
        }
    }

    #[test]
    fn tick_reports_exhaustion_and_recovers_after_release() {
        // Two sessions on one deliberately tiny shared arena: when the
        // pool runs dry mid-generation the tick reports the starved
        // session as `exhausted` (no panic, caches untouched, row
        // unconsumed); after the other session releases its blocks
        // (preemption, at the serving layer), re-ticking the SAME row
        // completes and stays bit-identical to an untouched solo run.
        let d = dims();
        let packed = PackedWeights::shared(d, 71);
        // Block size 4: an 8-row prefill fills 2 blocks/head exactly
        // (no slack) and a 5-row prefill takes 2 blocks/head with
        // slack; 2 heads -> 8 blocks, and the pool holds exactly 8.
        let arena = BlockArena::new(4, d.p, 8);
        let mk = |arena: &Arc<BlockArena>| {
            DecodeEngine::from_shared_arena(
                ItaConfig::tiny(),
                d,
                packed.weights.clone(),
                packed.weights_t.clone(),
                packed.requants,
                arena.clone(),
            )
        };
        let mut a = mk(&arena);
        let mut b = mk(&arena);
        let x = gen_input(72, &d);
        a.prefill(&x.block_padded(0, 0, 8, d.e));
        b.prefill(&x.block_padded(0, 0, 5, d.e));
        assert_eq!(arena.blocks_free(), 0);

        // Session b's step (5 -> 6) fits its reserved slack; session
        // a's step (8 -> 9) needs a fresh block per head — pool dry.
        let mut batch = FusedStepBatch::new();
        let rows = [x.row(8), x.row(5)];
        let report = {
            let mut refs: Vec<&mut DecodeEngine> = vec![&mut a, &mut b];
            batch.tick(&mut refs, &rows)
        };
        assert_eq!(report.exhausted, vec![0], "session a starved");
        assert!(report.poisoned.is_empty());
        assert_eq!(a.len(), 8, "starved session's caches untouched");
        assert_eq!(b.len(), 6, "survivor advanced normally");

        // Survivor output is bit-identical to a fault-free solo step.
        let mut solo_b = DecodeEngine::new(ItaConfig::tiny(), d, 71);
        solo_b.prefill(&x.block_padded(0, 0, 5, d.e));
        assert_eq!(batch.out_row(1), &solo_b.step(x.row(5))[..]);

        // Preempt b (the serving layer's move): its blocks return and
        // the SAME unconsumed row of a now completes, bit-identical.
        b.release_blocks();
        let report = {
            let mut refs: Vec<&mut DecodeEngine> = vec![&mut a];
            batch.tick(&mut refs, &rows[..1])
        };
        assert!(report.ok(), "{report:?}");
        let mut solo_a = DecodeEngine::new(ItaConfig::tiny(), d, 71);
        solo_a.prefill(&x.block_padded(0, 0, 8, d.e));
        assert_eq!(batch.out_row(0), &solo_a.step(x.row(8))[..], "retried step bit-exact");
    }

    #[test]
    fn fused_step_streams_each_weight_once() {
        // The acceptance assertion, at the unit level: one tick
        // charges exactly one weight stream per 3·H + 1 weight
        // matrices into `shared`, and each session's engine activity
        // equals its independent step minus exactly those streams —
        // every other counter bit-equal.
        use crate::ita::simulator::{activity_for_matmul, MatmulDims};
        let d = dims();
        let n = 3;
        let cfg = ItaConfig::tiny();
        let lens = [4usize, 1, 7];
        let mut fused: Vec<DecodeEngine> = (0..n).map(|_| DecodeEngine::new(cfg, d, 95)).collect();
        let mut indep: Vec<DecodeEngine> = (0..n).map(|_| DecodeEngine::new(cfg, d, 95)).collect();
        for (i, &l) in lens.iter().enumerate() {
            let prompt = gen_input(50 + i as u64, &d).block_padded(0, 0, l, d.e);
            fused[i].prefill(&prompt);
            indep[i].prefill(&prompt);
        }
        let x = gen_input(77, &d);
        let rows: Vec<&[i8]> = (0..n).map(|i| x.row(lens[i])).collect();
        let result = {
            let mut refs: Vec<&mut DecodeEngine> = fused.iter_mut().collect();
            fused_step(&mut refs, &rows)
        };

        let proj = activity_for_matmul(&cfg, MatmulDims { r: 0, k: d.e, c: d.p }, 0);
        let out_proj = activity_for_matmul(&cfg, MatmulDims { r: 0, k: d.h * d.p, c: d.e }, 0);
        let streams_once =
            3 * d.h as u64 * proj.weight_buf_writes + out_proj.weight_buf_writes;
        assert_eq!(result.shared.weight_buf_writes, streams_once);
        assert_eq!(result.shared.macs, 0);
        assert_eq!(result.shared.cycles, 0);

        let mut out = Vec::new();
        for i in 0..n {
            indep[i].engine.reset_activity();
            indep[i].step_into(rows[i], &mut out);
            let mut fused_act = fused[i].engine.activity;
            fused_act.weight_buf_writes += streams_once;
            assert_eq!(
                fused_act, indep[i].engine.activity,
                "session {i}: fused share must be independent-minus-streams exactly"
            );
        }
    }

    #[test]
    fn fused_step_single_session_matches_plain_step() {
        // N=1 is legal (the coordinator never routes it here, but the
        // library contract holds): one session's fused tick equals its
        // plain step, with the stream split still moved to `shared`.
        let d = dims();
        let mut a = DecodeEngine::new(ItaConfig::tiny(), d, 97);
        let mut b = DecodeEngine::new(ItaConfig::tiny(), d, 97);
        let x = gen_input(98, &d);
        a.prefill(&x.block_padded(0, 0, 6, d.e));
        b.prefill(&x.block_padded(0, 0, 6, d.e));
        let result = {
            let mut refs: Vec<&mut DecodeEngine> = vec![&mut a];
            fused_step(&mut refs, &[x.row(6)])
        };
        assert_eq!(result.outputs[0], b.step(x.row(6)));
        assert!(result.shared.weight_buf_writes > 0);
    }

    #[test]
    fn step_from_projected_matches_step_into() {
        // Hand-projecting q/k/v and feeding the attend half must leave
        // the engine (cache, attention rows, concat scratch) identical
        // to the self-projecting step.
        let d = dims();
        let mut plain = DecodeEngine::new(ItaConfig::tiny(), d, 99);
        let mut proj = DecodeEngine::new(ItaConfig::tiny(), d, 99);
        let x = gen_input(100, &d);
        plain.prefill(&x.block_padded(0, 0, 5, d.e));
        proj.prefill(&x.block_padded(0, 0, 5, d.e));
        let row = x.row(5);
        let mut out = Vec::new();
        plain.step_into(row, &mut out);

        let rq = proj.requants;
        let mut eng = TileEngine::new(ItaConfig::tiny());
        let x_row = MatI8::from_vec(1, d.e, row.to_vec());
        let qkv: Vec<(MatI8, MatI8, MatI8)> = proj
            .weights
            .heads
            .iter()
            .zip(&proj.weights_t.heads)
            .map(|(hw, (wqt, wkt, wvt))| {
                (
                    eng.linear_pret(&x_row, wqt, &hw.bq, rq.q),
                    eng.linear_pret(&x_row, wkt, &hw.bk, rq.k),
                    eng.linear_pret(&x_row, wvt, &hw.bv, rq.v),
                )
            })
            .collect();
        proj.step_from_projected(&qkv, 0);
        assert_eq!(proj.last_concat(), plain.last_concat(), "concat scratch");
        for h in 0..d.h {
            assert_eq!(proj.last_attn_row(h), plain.last_attn_row(h), "head {h}");
        }
        // Output projection over the concat equals the plain output.
        let mut got = Vec::new();
        eng.linear_row_pret(proj.last_concat(), &proj.weights_t.wot, &proj.weights.bo, rq.o, &mut got);
        assert_eq!(got, out);
        // Caches agree: the next step from both engines matches.
        assert_eq!(proj.len(), plain.len());
        assert_eq!(proj.step(x.row(6)), plain.step(x.row(6)));
    }

    #[test]
    fn deterministic_across_engines() {
        let d = dims();
        let mut a = DecodeEngine::new(ItaConfig::tiny(), d, 21);
        let mut b = DecodeEngine::new(ItaConfig::tiny(), d, 21);
        let mut rng = SplitMix64::new(22);
        let x = gen_input(23, &d);
        a.prefill(&x.block_padded(0, 0, 3, d.e));
        b.prefill(&x.block_padded(0, 0, 3, d.e));
        for _ in 0..5 {
            let row = rng.vec_i8(d.e);
            assert_eq!(a.step(&row), b.step(&row));
        }
    }
}
