//! Multi-head attention workload: weight containers, deterministic
//! model generation (bit-mirrored in Python), and execution on the
//! [`crate::ita::datapath::TileEngine`].
//!
//! Dataflow per Fig. 1/3: per head h,
//! `Q/K/V = requant(X·W_{q,k,v}^h + b)`, `A = ita_softmax(requant(Q·Kᵀ))`,
//! `O_h = requant(A·V + b_av)`; heads concatenated and projected with
//! `W_o`. All tensors int8 (A: uint8 probabilities at scale 2^−8).

pub mod decode;
pub mod encoder;
pub mod schedule;

// The fused multi-session entry points (§Prefill-batching /
// §Step-batching): stack N sessions' prompt rows (prefill) or pending
// token rows (decode tick) into one GEMM per projection weight.
// Re-exported here because they operate at the same altitude as
// `AttentionExecutor`/`run_attention_causal` — whole-model passes over
// the packed weight set — even though the per-session state they fill
// lives in `decode`.
pub use decode::{fused_prefill, fused_step, FusedPrefillResult, FusedStepBatch, FusedStepResult};

use crate::ita::datapath::TileEngine;
use crate::ita::requant::RequantParams;
use crate::ita::{Activity, ItaConfig};
use crate::util::mat::{MatI8, MatU8};
use crate::util::pool::{Task, WorkerPool};
use crate::util::rng::SplitMix64;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock, Weak};

/// Workload dimensions (paper Fig. 1 naming).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ModelDims {
    /// Sequence length.
    pub s: usize,
    /// Embedding size.
    pub e: usize,
    /// Projection size per head.
    pub p: usize,
    /// Number of heads.
    pub h: usize,
}

impl ModelDims {
    pub fn compact() -> Self {
        Self { s: 64, e: 128, p: 64, h: 2 }
    }

    pub fn shape(&self) -> crate::ita::simulator::AttentionShape {
        crate::ita::simulator::AttentionShape { s: self.s, e: self.e, p: self.p, h: self.h }
    }
}

/// One head's projection weights.
#[derive(Debug, Clone)]
pub struct HeadWeights {
    pub wq: MatI8, // E×P
    pub bq: Vec<i8>,
    pub wk: MatI8,
    pub bk: Vec<i8>,
    pub wv: MatI8,
    pub bv: Vec<i8>,
    /// Bias of the A·V output (the hardware's bias port in the AV pass).
    pub bav: Vec<i8>,
}

/// Full attention-block weights.
#[derive(Debug, Clone)]
pub struct AttentionWeights {
    pub heads: Vec<HeadWeights>,
    pub wo: MatI8, // (H·P)×E
    pub bo: Vec<i8>,
}

/// Requantization parameters for every stage.
#[derive(Debug, Clone, Copy)]
pub struct RequantConfig {
    pub q: RequantParams,
    pub k: RequantParams,
    pub v: RequantParams,
    pub qk: RequantParams,
    pub av: RequantParams,
    pub o: RequantParams,
}

/// Standard deviation of a uniform int8 code (discrete uniform on
/// [−128, 127]): sqrt((256² − 1)/12) ≈ 73.9. Used by the deterministic
/// requant derivation below (mirrored in `python/compile/quant.py`).
pub const UNIFORM_I8_VAR: f64 = (256.0 * 256.0 - 1.0) / 12.0;
/// Target post-requant standard deviation (±4σ inside int8).
pub const TARGET_STD: f64 = 32.0;

/// Deterministic requant derivation for the synthetic workloads: one
/// formula per stage, computed only from the model dimensions. Both
/// the Rust golden model and the JAX model call their mirrored copy,
/// which keeps the layers bit-identical without serializing scales.
pub fn default_requants(d: &ModelDims) -> RequantConfig {
    let proj_acc_std = UNIFORM_I8_VAR * (d.e as f64).sqrt();
    let proj = RequantParams::from_scale(TARGET_STD / proj_acc_std);
    // Q,K post-requant std ≈ TARGET_STD ⇒ logit accumulation std:
    let qk_acc_std = TARGET_STD * TARGET_STD * (d.p as f64).sqrt();
    // Logit std target 48: exercises the softmax window (±2.77/ε≈128).
    let qk = RequantParams::from_scale(48.0 / qk_acc_std);
    // A rows sum to ~256 (uint8, scale 2^−8); value std TARGET_STD.
    let av_acc_std = TARGET_STD * 256.0 / (d.s as f64).sqrt();
    let av = RequantParams::from_scale(TARGET_STD / av_acc_std);
    let o_acc_std = TARGET_STD * UNIFORM_I8_VAR.sqrt() * ((d.h * d.p) as f64).sqrt();
    let o = RequantParams::from_scale(TARGET_STD / o_acc_std);
    RequantConfig { q: proj, k: proj, v: proj, qk, av, o }
}

/// Deterministically generate attention weights from a seed.
///
/// Stream order (MUST stay in sync with `python/compile/model.py`):
/// per head: Wq (E·P row-major), bq (P), Wk, bk, Wv, bv, bav (P);
/// then Wo ((H·P)·E), bo (E). All values full-range uniform int8.
pub fn gen_weights(seed: u64, d: &ModelDims) -> AttentionWeights {
    let mut rng = SplitMix64::new(seed);
    fn mat(rng: &mut SplitMix64, r: usize, c: usize) -> MatI8 {
        MatI8::from_vec(r, c, rng.vec_i8(r * c))
    }
    let heads = (0..d.h)
        .map(|_| {
            let wq = mat(&mut rng, d.e, d.p);
            let bq = rng.vec_i8(d.p);
            let wk = mat(&mut rng, d.e, d.p);
            let bk = rng.vec_i8(d.p);
            let wv = mat(&mut rng, d.e, d.p);
            let bv = rng.vec_i8(d.p);
            let bav = rng.vec_i8(d.p);
            HeadWeights { wq, bq, wk, bk, wv, bv, bav }
        })
        .collect();
    let wo = mat(&mut rng, d.h * d.p, d.e);
    let bo = rng.vec_i8(d.e);
    AttentionWeights { heads, wo, bo }
}

/// Deterministically generate an int8 input activation matrix.
pub fn gen_input(seed: u64, d: &ModelDims) -> MatI8 {
    let mut rng = SplitMix64::new(seed);
    MatI8::from_vec(d.s, d.e, rng.vec_i8(d.s * d.e))
}

/// Result of one attention execution.
#[derive(Debug, Clone)]
pub struct AttentionOutput {
    /// Final S×E output.
    pub out: MatI8,
    /// Per-head attention probability matrices (for Fig. 5 / tests).
    pub attn: Vec<MatU8>,
}

/// Execute a full multi-head attention block on the ITA engine.
/// This is the golden numeric reference for all layers.
pub fn run_attention(
    engine: &mut TileEngine,
    x: &MatI8,
    w: &AttentionWeights,
    rq: &RequantConfig,
) -> AttentionOutput {
    let mut head_outputs: Vec<MatI8> = Vec::with_capacity(w.heads.len());
    let mut attn = Vec::with_capacity(w.heads.len());
    for hw in &w.heads {
        let q = engine.linear(x, &hw.wq, &hw.bq, rq.q);
        let k = engine.linear(x, &hw.wk, &hw.bk, rq.k);
        let v = engine.linear(x, &hw.wv, &hw.bv, rq.v);
        let (o, a) = engine.attention_core(&q, &k, &v, rq.qk, &hw.bav, rq.av);
        head_outputs.push(o);
        attn.push(a);
    }
    // Concatenate heads along the feature dimension, project.
    let out = engine.linear(&concat_heads(&head_outputs), &w.wo, &w.bo, rq.o);
    AttentionOutput { out, attn }
}

/// Pre-change execution on the naive oracle kernels
/// ([`TileEngine::linear_reference`] /
/// [`TileEngine::attention_core_reference`]): the bit-exactness oracle
/// for [`run_attention`] and the "before" side of
/// `benches/hotpath.rs`'s speedup measurement.
pub fn run_attention_reference(
    engine: &mut TileEngine,
    x: &MatI8,
    w: &AttentionWeights,
    rq: &RequantConfig,
) -> AttentionOutput {
    let mut head_outputs: Vec<MatI8> = Vec::with_capacity(w.heads.len());
    let mut attn = Vec::with_capacity(w.heads.len());
    for hw in &w.heads {
        let q = engine.linear_reference(x, &hw.wq, &hw.bq, rq.q);
        let k = engine.linear_reference(x, &hw.wk, &hw.bk, rq.k);
        let v = engine.linear_reference(x, &hw.wv, &hw.bv, rq.v);
        let (o, a) = engine.attention_core_reference(&q, &k, &v, rq.qk, &hw.bav, rq.av);
        head_outputs.push(o);
        attn.push(a);
    }
    let mut concat = head_outputs[0].clone();
    for o in &head_outputs[1..] {
        concat = concat.hcat(o);
    }
    let out = engine.linear_reference(&concat, &w.wo, &w.bo, rq.o);
    AttentionOutput { out, attn }
}

/// Shared body of the causal runners: per-head Q/K/V from `qkv`
/// (which also gets the head index, so callers can tap the projected
/// rows — the decode prefill fills its KV caches there), then the
/// causal core. Returns per-head outputs and attention matrices.
fn run_causal_heads(
    engine: &mut TileEngine,
    w: &AttentionWeights,
    rq: &RequantConfig,
    mut qkv: impl FnMut(&mut TileEngine, usize, &HeadWeights) -> (MatI8, MatI8, MatI8),
) -> (Vec<MatI8>, Vec<MatU8>) {
    let mut head_outputs = Vec::with_capacity(w.heads.len());
    let mut attn = Vec::with_capacity(w.heads.len());
    for (h, hw) in w.heads.iter().enumerate() {
        let (q, k, v) = qkv(engine, h, hw);
        let (o, a) = engine.attention_core_causal(&q, &k, &v, rq.qk, &hw.bav, rq.av);
        head_outputs.push(o);
        attn.push(a);
    }
    (head_outputs, attn)
}

/// Concatenate per-head outputs along the feature dimension in one
/// pass (the pairwise `hcat` chain copies O(H²) data).
fn concat_heads(parts: &[MatI8]) -> MatI8 {
    let rows = parts[0].rows();
    let total: usize = parts.iter().map(|p| p.cols()).sum();
    let mut out = MatI8::zeros(rows, total);
    for r in 0..rows {
        let orow = out.row_mut(r);
        let mut c0 = 0;
        for p in parts {
            orow[c0..c0 + p.cols()].copy_from_slice(p.row(r));
            c0 += p.cols();
        }
    }
    out
}

/// Causal (decoder) counterpart of [`run_attention`]: per head
/// Q/K/V projections, the causal core (row r attends to columns 0..=r),
/// concatenation, output projection. This is the **full-recompute
/// oracle** the incremental decode path
/// ([`decode::DecodeEngine`]) is pinned bit-identical to
/// (`tests/decode_parity.rs`), and the "before" side of
/// `benches/decode.rs`.
pub fn run_attention_causal(
    engine: &mut TileEngine,
    x: &MatI8,
    w: &AttentionWeights,
    rq: &RequantConfig,
) -> AttentionOutput {
    let (head_outputs, attn) = run_causal_heads(engine, w, rq, |e, _h, hw| {
        (
            e.linear(x, &hw.wq, &hw.bq, rq.q),
            e.linear(x, &hw.wk, &hw.bk, rq.k),
            e.linear(x, &hw.wv, &hw.bv, rq.v),
        )
    });
    let out = engine.linear(&concat_heads(&head_outputs), &w.wo, &w.bo, rq.o);
    AttentionOutput { out, attn }
}

/// Pre-transposed weight cache (§Perf): the serving path pays each
/// weight transpose once at model load — the software expression of
/// ITA's weight-stationary buffer.
#[derive(Debug, Clone)]
pub struct TransposedWeights {
    /// Per head: (Wqᵀ, Wkᵀ, Wvᵀ), each P×E.
    pub heads: Vec<(MatI8, MatI8, MatI8)>,
    /// Woᵀ, E×(H·P).
    pub wot: MatI8,
}

impl TransposedWeights {
    pub fn of(w: &AttentionWeights) -> Self {
        Self {
            heads: w
                .heads
                .iter()
                .map(|h| (h.wq.transpose(), h.wk.transpose(), h.wv.transpose()))
                .collect(),
            wot: w.wo.transpose(),
        }
    }
}

/// One fully-packed weight set: the generated weights, their
/// once-packed transposes, and the derived requant parameters —
/// everything request execution needs that is a pure function of
/// `(seed, dims)`.
///
/// §Perf: instances live in a process-wide cache keyed by weight
/// identity, so every executor, decode session, and coordinator worker
/// serving the same model shares ONE packing pass (`Arc`-shared,
/// read-only at serve time) instead of regenerating and re-transposing
/// per engine — the software expression of ITA's weight-stationary
/// buffer being written once and reused across tiles.
#[derive(Debug)]
pub struct PackedWeights {
    pub dims: ModelDims,
    pub seed: u64,
    pub weights: Arc<AttentionWeights>,
    pub weights_t: Arc<TransposedWeights>,
    pub requants: RequantConfig,
}

impl PackedWeights {
    /// Build (and pack) a weight set without touching the cache.
    pub fn generate(dims: ModelDims, seed: u64) -> Arc<Self> {
        let weights = Arc::new(gen_weights(seed, &dims));
        let weights_t = Arc::new(TransposedWeights::of(&weights));
        Arc::new(Self { dims, seed, weights, weights_t, requants: default_requants(&dims) })
    }

    /// The process-wide packed-weight cache: one entry per weight
    /// identity `(seed, dims)`. Entries are held weakly — a model with
    /// no remaining user costs nothing; a live one is packed exactly
    /// once no matter how many executors/sessions serve it.
    pub fn shared(dims: ModelDims, seed: u64) -> Arc<Self> {
        type Cache = Mutex<HashMap<(u64, ModelDims), Weak<PackedWeights>>>;
        static CACHE: OnceLock<Cache> = OnceLock::new();
        let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
        let mut map = cache.lock().unwrap();
        if let Some(hit) = map.get(&(seed, dims)).and_then(Weak::upgrade) {
            return hit;
        }
        // Generation under the lock keeps the cache single-assignment
        // (two racing misses would otherwise pack twice and share
        // nothing); model generation is fast relative to serving one
        // request, so the brief critical section is acceptable.
        let packed = Self::generate(dims, seed);
        map.retain(|_, w| w.strong_count() > 0);
        map.insert((seed, dims), Arc::downgrade(&packed));
        packed
    }
}

/// Convenience wrapper owning the engine.
pub struct AttentionExecutor {
    pub engine: TileEngine,
    /// One persistent engine per head for the pooled [`Self::run`]
    /// path: scratch arenas stay warm across calls (§Perf) and each
    /// pool task gets exclusive `&mut` access to its own engine.
    head_engines: Vec<TileEngine>,
    /// Weight set shared via the [`PackedWeights`] cache — executors
    /// serving the same `(seed, dims)` hold the same allocation.
    pub weights: Arc<AttentionWeights>,
    /// Transposed copies, packed once per weight set (not per
    /// executor, not per call).
    pub weights_t: Arc<TransposedWeights>,
    pub requants: RequantConfig,
    pub dims: ModelDims,
}

/// One head's full pipeline (projections + fused attention core) on
/// that head's persistent engine. The engine's activity is reset on
/// entry, so the returned copy is exactly this call's delta. Free
/// function so the pool tasks in [`AttentionExecutor::run`] can call
/// it without borrowing `self`.
fn run_head(
    engine: &mut TileEngine,
    x: &MatI8,
    hw: &HeadWeights,
    wts: &(MatI8, MatI8, MatI8),
    rq: RequantConfig,
) -> (MatI8, MatU8, Activity) {
    engine.reset_activity();
    let (wqt, wkt, wvt) = wts;
    let q = engine.linear_pret(x, wqt, &hw.bq, rq.q);
    let k = engine.linear_pret(x, wkt, &hw.bk, rq.k);
    let v = engine.linear_pret(x, wvt, &hw.bv, rq.v);
    let (o, a) = engine.attention_core(&q, &k, &v, rq.qk, &hw.bav, rq.av);
    (o, a, engine.activity)
}

impl AttentionExecutor {
    /// Construct over the [`PackedWeights`] cache: the first executor
    /// for a `(seed, dims)` pair generates and packs the model; every
    /// subsequent one (coordinator pool growth, parallel tests) only
    /// clones two `Arc`s and allocates its private engines.
    pub fn new(cfg: ItaConfig, dims: ModelDims, seed: u64) -> Self {
        Self::from_packed(cfg, PackedWeights::shared(dims, seed))
    }

    /// Construct around an explicit packed weight set.
    pub fn from_packed(cfg: ItaConfig, packed: Arc<PackedWeights>) -> Self {
        let dims = packed.dims;
        Self {
            engine: TileEngine::new(cfg),
            head_engines: (0..dims.h).map(|_| TileEngine::new(cfg)).collect(),
            weights: packed.weights.clone(),
            weights_t: packed.weights_t.clone(),
            requants: packed.requants,
            dims,
        }
    }

    /// Bit-identical to [`run_attention`] but uses the pre-transposed
    /// weight cache and executes the H heads on the persistent
    /// [`WorkerPool`] (§Perf — no thread spawn per call; PR-1 spawned
    /// scoped threads per batch). Each pool task owns a task-private
    /// [`TileEngine`]; head outputs and [`Activity`] counters are
    /// merged back in head order, so the result — outputs AND
    /// accounting — is deterministic and identical to
    /// [`AttentionExecutor::run_serial`] (asserted in tests: `Activity`
    /// merging is a sum of event counters, which is order-invariant).
    pub fn run(&mut self, x: &MatI8) -> AttentionOutput {
        if self.weights.heads.len() <= 1 {
            return self.run_serial(x);
        }
        let (w, wt, rq) = (&self.weights, &self.weights_t, self.requants);

        let mut head_results: Vec<Option<(MatI8, MatU8, Activity)>> =
            (0..w.heads.len()).map(|_| None).collect();
        let tasks: Vec<Task> = self
            .head_engines
            .iter_mut()
            .zip(w.heads.iter().zip(&wt.heads))
            .zip(head_results.iter_mut())
            .map(|((eng, (hw, wts)), slot)| {
                Box::new(move || *slot = Some(run_head(eng, x, hw, wts, rq))) as Task
            })
            .collect();
        WorkerPool::global().run(tasks);

        let mut head_outputs: Vec<MatI8> = Vec::with_capacity(head_results.len());
        let mut attn = Vec::with_capacity(head_results.len());
        for r in head_results {
            let (o, a, activity) = r.expect("head task completed");
            self.engine.activity.add(&activity);
            head_outputs.push(o);
            attn.push(a);
        }
        let out = self.engine.linear_pret(&concat_heads(&head_outputs), &wt.wot, &w.bo, rq.o);
        AttentionOutput { out, attn }
    }

    /// Single-threaded execution on the shared engine — the pre-change
    /// `run` body. Kept callable for the determinism tests and as the
    /// single-thread-normalized "before" side of the threading speedup
    /// in `benches/hotpath.rs`.
    pub fn run_serial(&mut self, x: &MatI8) -> AttentionOutput {
        let (w, wt, rq) = (&self.weights, &self.weights_t, &self.requants);
        let engine = &mut self.engine;
        let mut head_outputs: Vec<MatI8> = Vec::with_capacity(w.heads.len());
        let mut attn = Vec::with_capacity(w.heads.len());
        for (hw, wts) in w.heads.iter().zip(&wt.heads) {
            let (wqt, wkt, wvt) = wts;
            let q = engine.linear_pret(x, wqt, &hw.bq, rq.q);
            let k = engine.linear_pret(x, wkt, &hw.bk, rq.k);
            let v = engine.linear_pret(x, wvt, &hw.bv, rq.v);
            let (o, a) = engine.attention_core(&q, &k, &v, rq.qk, &hw.bav, rq.av);
            head_outputs.push(o);
            attn.push(a);
        }
        let out = engine.linear_pret(&concat_heads(&head_outputs), &wt.wot, &w.bo, rq.o);
        AttentionOutput { out, attn }
    }

    /// Causal execution on the shared engine with the pre-transposed
    /// weight cache — bit-identical to [`run_attention_causal`] and the
    /// full-recompute baseline for the decode bench.
    pub fn run_causal(&mut self, x: &MatI8) -> AttentionOutput {
        let (w, wt, rq) = (&self.weights, &self.weights_t, &self.requants);
        let engine = &mut self.engine;
        let (head_outputs, attn) = run_causal_heads(engine, w, rq, |e, h, hw| {
            let (wqt, wkt, wvt) = &wt.heads[h];
            (
                e.linear_pret(x, wqt, &hw.bq, rq.q),
                e.linear_pret(x, wkt, &hw.bk, rq.k),
                e.linear_pret(x, wvt, &hw.bv, rq.v),
            )
        });
        let out = engine.linear_pret(&concat_heads(&head_outputs), &wt.wot, &w.bo, rq.o);
        AttentionOutput { out, attn }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ita::ItaConfig;

    fn tiny_dims() -> ModelDims {
        ModelDims { s: 16, e: 16, p: 8, h: 2 }
    }

    #[test]
    fn weight_generation_deterministic() {
        let d = tiny_dims();
        let a = gen_weights(42, &d);
        let b = gen_weights(42, &d);
        assert_eq!(a.wo, b.wo);
        assert_eq!(a.heads[1].wv, b.heads[1].wv);
        let c = gen_weights(43, &d);
        assert_ne!(a.wo, c.wo, "different seeds differ");
    }

    #[test]
    fn packed_weight_cache_shares_one_packing_per_identity() {
        let d = tiny_dims();
        let a = PackedWeights::shared(d, 7001);
        let b = PackedWeights::shared(d, 7001);
        // Same identity → the very same allocations (weights AND packs).
        assert!(Arc::ptr_eq(&a, &b));
        let ex1 = AttentionExecutor::new(ItaConfig::tiny(), d, 7001);
        let ex2 = AttentionExecutor::new(ItaConfig::tiny(), d, 7001);
        assert!(Arc::ptr_eq(&ex1.weights, &ex2.weights));
        assert!(Arc::ptr_eq(&ex1.weights_t, &ex2.weights_t));
        assert!(Arc::ptr_eq(&a.weights, &ex1.weights));
        // Different seed or dims → distinct models.
        let c = PackedWeights::shared(d, 7002);
        assert!(!Arc::ptr_eq(&a, &c));
        assert_ne!(a.weights.wo, c.weights.wo);
        let d2 = ModelDims { s: d.s + 1, ..d };
        let e = PackedWeights::shared(d2, 7001);
        assert!(!Arc::ptr_eq(&a.weights, &e.weights));
        // And the packs really are the transposes of the weights.
        assert_eq!(a.weights_t.wot, a.weights.wo.transpose());
    }

    #[test]
    fn output_shape_and_determinism() {
        let d = tiny_dims();
        let mut ex = AttentionExecutor::new(ItaConfig::tiny(), d, 1);
        let x = gen_input(2, &d);
        let out1 = ex.run(&x);
        assert_eq!(out1.out.shape(), (d.s, d.e));
        assert_eq!(out1.attn.len(), d.h);
        assert_eq!(out1.attn[0].shape(), (d.s, d.s));
        let out2 = ex.run(&x);
        assert_eq!(out1.out, out2.out);
    }

    #[test]
    fn cached_transpose_path_matches_plain_run_attention() {
        // The §Perf pre-transposed path must be bit-identical to the
        // reference run_attention.
        let d = ModelDims { s: 24, e: 32, p: 16, h: 3 };
        let mut ex = AttentionExecutor::new(ItaConfig::tiny(), d, 5);
        let x = gen_input(6, &d);
        let fast = ex.run(&x);
        let mut engine = TileEngine::new(ItaConfig::tiny());
        let slow = run_attention(&mut engine, &x, &ex.weights, &ex.requants);
        assert_eq!(fast.out, slow.out);
        assert_eq!(fast.attn, slow.attn);
        // Activity accounting identical too.
        assert_eq!(ex.engine.activity, engine.activity);
    }

    #[test]
    fn parallel_heads_deterministic_and_match_serial() {
        // The issue's determinism contract: multi-threaded run()
        // output AND merged Activity equal the serial path, run after
        // run.
        let d = ModelDims { s: 24, e: 32, p: 16, h: 4 };
        let mut par = AttentionExecutor::new(ItaConfig::tiny(), d, 9);
        let mut ser = AttentionExecutor::new(ItaConfig::tiny(), d, 9);
        for seed in [1u64, 2, 3] {
            // Fresh counters each round: the extra repeat-run below
            // would otherwise skew the parallel side's totals.
            par.engine.reset_activity();
            ser.engine.reset_activity();
            let x = gen_input(seed, &d);
            let a = par.run(&x);
            let b = ser.run_serial(&x);
            assert_eq!(a.out, b.out, "seed {seed}");
            assert_eq!(a.attn, b.attn, "seed {seed}");
            assert_eq!(par.engine.activity, ser.engine.activity, "seed {seed}");
            // Repeat the parallel run: bit-identical again.
            let a2 = par.run(&x);
            assert_eq!(a.out, a2.out);
            assert_eq!(a.attn, a2.attn);
        }
    }

    #[test]
    fn blocked_run_matches_reference_oracle_run() {
        // Full-block pin: the blocked-kernel path (run_attention and
        // the threaded executor) against the retained pre-change
        // oracle kernels.
        let d = ModelDims { s: 24, e: 32, p: 16, h: 2 };
        let mut ex = AttentionExecutor::new(ItaConfig::tiny(), d, 13);
        let x = gen_input(14, &d);
        let fast = ex.run(&x);
        let mut engine = TileEngine::new(ItaConfig::tiny());
        let oracle = run_attention_reference(&mut engine, &x, &ex.weights, &ex.requants);
        assert_eq!(fast.out, oracle.out);
        assert_eq!(fast.attn, oracle.attn);
        assert_eq!(ex.engine.activity, engine.activity);
    }

    #[test]
    fn run_causal_matches_plain_causal_runner() {
        // Pre-transposed executor path vs the transpose-per-call
        // reference: outputs, attention, and activity all identical.
        let d = ModelDims { s: 24, e: 32, p: 16, h: 3 };
        let mut ex = AttentionExecutor::new(ItaConfig::tiny(), d, 17);
        let x = gen_input(18, &d);
        let fast = ex.run_causal(&x);
        let mut engine = TileEngine::new(ItaConfig::tiny());
        let slow = run_attention_causal(&mut engine, &x, &ex.weights, &ex.requants);
        assert_eq!(fast.out, slow.out);
        assert_eq!(fast.attn, slow.attn);
        assert_eq!(ex.engine.activity, engine.activity);
        // Causal masking visible: strictly-upper entries are zero.
        for h in 0..d.h {
            for r in 0..d.s {
                assert!(fast.attn[h].row(r)[r + 1..].iter().all(|&v| v == 0));
            }
        }
    }

    #[test]
    fn causal_full_row_equals_unmasked_last_row() {
        // The last causal row attends to everything: it must equal the
        // unmasked run's last row through the full multi-head pipeline.
        let d = ModelDims { s: 16, e: 16, p: 8, h: 2 };
        let mut ex = AttentionExecutor::new(ItaConfig::tiny(), d, 19);
        let x = gen_input(20, &d);
        let causal = ex.run_causal(&x);
        let mut ex2 = AttentionExecutor::new(ItaConfig::tiny(), d, 19);
        let full = ex2.run_serial(&x);
        assert_eq!(causal.out.row(d.s - 1), full.out.row(d.s - 1));
    }

    #[test]
    fn activity_matches_simulator_prediction() {
        // The functional engine's MAC count must equal the analytic
        // workload model exactly.
        let d = ModelDims { s: 24, e: 32, p: 16, h: 2 };
        let mut ex = AttentionExecutor::new(ItaConfig::tiny(), d, 3);
        let x = gen_input(4, &d);
        let _ = ex.run(&x);
        assert_eq!(ex.engine.activity.macs, d.shape().total_macs());
    }

    #[test]
    fn attention_rows_valid_distributions() {
        let d = ModelDims { s: 32, e: 32, p: 16, h: 1 };
        let mut ex = AttentionExecutor::new(ItaConfig::tiny(), d, 7);
        let x = gen_input(8, &d);
        let out = ex.run(&x);
        for r in 0..d.s {
            let mass: f64 = out.attn[0].row(r).iter().map(|&v| v as f64 / 256.0).sum();
            // Shift-floor quantization can cost up to ~half the mass on
            // adversarial rows (every term just past a shift boundary).
            assert!(mass > 0.4 && mass < 1.3, "row {r} mass {mass}");
        }
    }

    #[test]
    fn logits_exercise_softmax_range() {
        // The deterministic requant derivation must place QKᵀ logits in
        // a range where softmax output is non-trivial (not all-uniform,
        // not all-saturated): check attention rows have spread.
        let d = ModelDims { s: 32, e: 64, p: 32, h: 1 };
        let mut ex = AttentionExecutor::new(ItaConfig::tiny(), d, 11);
        let x = gen_input(12, &d);
        let out = ex.run(&x);
        let a = &out.attn[0];
        let mut nonuniform_rows = 0;
        for r in 0..d.s {
            let row = a.row(r);
            let max = *row.iter().max().unwrap();
            let min = *row.iter().min().unwrap();
            if max > min + 4 {
                nonuniform_rows += 1;
            }
        }
        assert!(nonuniform_rows > d.s / 2, "only {nonuniform_rows} rows show structure");
    }
}
