//! Quantized transformer encoder layer on the ITA engine.
//!
//! The paper accelerates the attention block; a full encoder layer
//! additionally has residual connections and a feed-forward network
//! whose two linears map onto the same PE array ("ITA computes linear
//! layers sequentially", §III). Residual adds are saturating int8 adds
//! (host-side in a real deployment, bit-exactly modeled here);
//! normalization is folded into the requantization scales, as in
//! integer-only deployments of quantized transformers (I-BERT-style) —
//! documented as a substitution in DESIGN.md.

use super::{default_requants, gen_weights, AttentionWeights, ModelDims, RequantConfig};
use crate::ita::datapath::TileEngine;
use crate::ita::requant::RequantParams;
use crate::util::mat::MatI8;
use crate::util::rng::SplitMix64;

/// Feed-forward weights: E → F → E.
#[derive(Debug, Clone)]
pub struct FfnWeights {
    pub w1: MatI8, // E×F
    pub b1: Vec<i8>,
    pub w2: MatI8, // F×E
    pub b2: Vec<i8>,
}

/// One encoder layer's parameters.
#[derive(Debug, Clone)]
pub struct EncoderLayer {
    pub attn: AttentionWeights,
    pub ffn: FfnWeights,
}

/// Whole encoder model.
#[derive(Debug, Clone)]
pub struct EncoderModel {
    pub dims: ModelDims,
    /// FFN inner dimension.
    pub f: usize,
    pub layers: Vec<EncoderLayer>,
    pub rq: RequantConfig,
    pub rq_ffn1: RequantParams,
    pub rq_ffn2: RequantParams,
}

impl EncoderModel {
    /// Deterministic model generation. Stream order (mirrored in
    /// `python/compile/model.py`): per layer, the attention weights
    /// (seed `seed + 1000·layer`), then W1 (E·F), b1 (F), W2 (F·E),
    /// b2 (E) from seed `seed + 1000·layer + 500`.
    pub fn generate(dims: ModelDims, f: usize, n_layers: usize, seed: u64) -> Self {
        let mut layers = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            let attn = gen_weights(seed + 1000 * l as u64, &dims);
            let mut rng = SplitMix64::new(seed + 1000 * l as u64 + 500);
            let w1 = MatI8::from_vec(dims.e, f, rng.vec_i8(dims.e * f));
            let b1 = rng.vec_i8(f);
            let w2 = MatI8::from_vec(f, dims.e, rng.vec_i8(f * dims.e));
            let b2 = rng.vec_i8(dims.e);
            layers.push(EncoderLayer { attn, ffn: FfnWeights { w1, b1, w2, b2 } });
        }
        let rq = default_requants(&dims);
        // FFN requants: same deterministic derivation as projections.
        let acc1 = super::UNIFORM_I8_VAR * (dims.e as f64).sqrt();
        let rq_ffn1 = RequantParams::from_scale(super::TARGET_STD / acc1);
        let acc2 = super::TARGET_STD * super::UNIFORM_I8_VAR.sqrt() * (f as f64).sqrt();
        let rq_ffn2 = RequantParams::from_scale(super::TARGET_STD / acc2);
        Self { dims, f, layers, rq, rq_ffn1, rq_ffn2 }
    }

    /// Total useful MACs per token sequence (all layers).
    pub fn total_macs(&self) -> u64 {
        let per_attn = self.dims.shape().total_macs();
        let per_ffn = 2 * (self.dims.s * self.dims.e * self.f) as u64;
        (per_attn + per_ffn) * self.layers.len() as u64
    }
}

/// Saturating int8 residual add (host-side op).
pub fn residual_add(a: &MatI8, b: &MatI8) -> MatI8 {
    assert_eq!(a.shape(), b.shape());
    MatI8::from_fn(a.rows(), a.cols(), |r, c| a.get(r, c).saturating_add(b.get(r, c)))
}

/// Integer ReLU.
pub fn relu_i8(x: &MatI8) -> MatI8 {
    x.map(|v| v.max(0))
}

/// Run the full encoder on the engine; returns per-layer outputs' final
/// activation. Attention blocks and FFN linears both run on the blocked
/// GEMM kernels with fused requant (§Perf).
pub fn run_encoder(engine: &mut TileEngine, model: &EncoderModel, x: &MatI8) -> MatI8 {
    let mut h = x.clone();
    for layer in &model.layers {
        let attn_out = super::run_attention(engine, &h, &layer.attn, &model.rq);
        let h1 = residual_add(&h, &attn_out.out);
        let ff1 = relu_i8(&engine.linear(&h1, &layer.ffn.w1, &layer.ffn.b1, model.rq_ffn1));
        let ff2 = engine.linear(&ff1, &layer.ffn.w2, &layer.ffn.b2, model.rq_ffn2);
        h = residual_add(&h1, &ff2);
    }
    h
}

/// Pre-change encoder on the naive oracle kernels — the bit-exactness
/// oracle for [`run_encoder`] (see `TileEngine::linear_reference`).
pub fn run_encoder_reference(engine: &mut TileEngine, model: &EncoderModel, x: &MatI8) -> MatI8 {
    let mut h = x.clone();
    for layer in &model.layers {
        let attn_out = super::run_attention_reference(engine, &h, &layer.attn, &model.rq);
        let h1 = residual_add(&h, &attn_out.out);
        let ff1 =
            relu_i8(&engine.linear_reference(&h1, &layer.ffn.w1, &layer.ffn.b1, model.rq_ffn1));
        let ff2 = engine.linear_reference(&ff1, &layer.ffn.w2, &layer.ffn.b2, model.rq_ffn2);
        h = residual_add(&h1, &ff2);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::gen_input;
    use crate::ita::ItaConfig;

    fn tiny_model() -> EncoderModel {
        EncoderModel::generate(ModelDims { s: 16, e: 16, p: 8, h: 2 }, 32, 2, 9)
    }

    #[test]
    fn deterministic_generation() {
        let a = tiny_model();
        let b = tiny_model();
        assert_eq!(a.layers[1].ffn.w1, b.layers[1].ffn.w1);
        assert_eq!(a.layers[0].attn.wo, b.layers[0].attn.wo);
    }

    #[test]
    fn encoder_runs_and_is_deterministic() {
        let model = tiny_model();
        let x = gen_input(1, &model.dims);
        let mut e1 = TileEngine::new(ItaConfig::tiny());
        let mut e2 = TileEngine::new(ItaConfig::tiny());
        let y1 = run_encoder(&mut e1, &model, &x);
        let y2 = run_encoder(&mut e2, &model, &x);
        assert_eq!(y1, y2);
        assert_eq!(y1.shape(), (16, 16));
    }

    #[test]
    fn encoder_blocked_kernels_match_oracle() {
        // run_encoder (blocked GEMM + fused requant) vs the retained
        // naive-kernel reference: outputs and activity bit-identical.
        let model = tiny_model();
        let x = gen_input(3, &model.dims);
        let mut e1 = TileEngine::new(ItaConfig::tiny());
        let mut e2 = TileEngine::new(ItaConfig::tiny());
        let y1 = run_encoder(&mut e1, &model, &x);
        let y2 = run_encoder_reference(&mut e2, &model, &x);
        assert_eq!(y1, y2);
        assert_eq!(e1.activity, e2.activity);
    }

    #[test]
    fn residual_saturates() {
        let a = MatI8::from_vec(1, 2, vec![120, -120]);
        let b = MatI8::from_vec(1, 2, vec![20, -20]);
        let r = residual_add(&a, &b);
        assert_eq!(r.as_slice(), &[127, -128]);
    }

    #[test]
    fn relu_clamps_negatives() {
        let x = MatI8::from_vec(1, 3, vec![-5, 0, 5]);
        assert_eq!(relu_i8(&x).as_slice(), &[0, 0, 5]);
    }

    #[test]
    fn mac_accounting_includes_ffn() {
        let model = tiny_model();
        let x = gen_input(1, &model.dims);
        let mut e = TileEngine::new(ItaConfig::tiny());
        let _ = run_encoder(&mut e, &model, &x);
        assert_eq!(e.activity.macs, model.total_macs());
    }
}
