//! Tile-level schedule generation (Fig. 3's workload mapping).
//!
//! Expands an attention workload into the ordered sequence of tile
//! operations the hardware executes: per phase, the (row-tile,
//! depth-tile, column-group) loop nest with weight-set changes marked.
//! The coordinator uses this to interleave requests; the cycle-exact
//! simulator walks it; tests assert its totals equal the analytic
//! model.

use crate::ita::simulator::{tiles_ceil, AttentionShape, MatmulDims};
use crate::ita::ItaConfig;

/// Phase identifiers in schedule order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Q,
    K,
    V,
    QK,
    AV,
    OW,
}

impl Phase {
    pub fn name(&self) -> &'static str {
        match self {
            Phase::Q => "Q",
            Phase::K => "K",
            Phase::V => "V",
            Phase::QK => "QK^T",
            Phase::AV => "AV",
            Phase::OW => "OW",
        }
    }
}

/// One tile operation: M cycles of PE-array work on one weight set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileOp {
    pub phase: Phase,
    pub head: usize,
    /// Row-tile index (output rows `row_tile·M ..`).
    pub row_tile: usize,
    /// Depth-tile index along the reduction dimension.
    pub depth_tile: usize,
    /// Column group index (output columns `col_group·N ..`).
    pub col_group: usize,
    /// True when this op needs a new weight set in the buffer.
    pub loads_weights: bool,
    /// True when this op's outputs complete (last depth tile).
    pub produces_output: bool,
    /// Softmax side effects riding on this op.
    pub softmax_da: bool,
    pub softmax_en: bool,
}

/// Generate the loop nest for one matmul phase.
fn matmul_ops(
    cfg: &ItaConfig,
    phase: Phase,
    head: usize,
    d: MatmulDims,
    out: &mut Vec<TileOp>,
) {
    let rt = tiles_ceil(d.r, cfg.m);
    let kt = tiles_ceil(d.k, cfg.m);
    let cg = tiles_ceil(d.c, cfg.n);
    for row_tile in 0..rt {
        for col_group in 0..cg {
            for depth_tile in 0..kt {
                out.push(TileOp {
                    phase,
                    head,
                    row_tile,
                    depth_tile,
                    col_group,
                    loads_weights: true, // weights change every (group, depth) step
                    produces_output: depth_tile == kt - 1,
                    softmax_da: phase == Phase::QK && depth_tile == kt - 1,
                    softmax_en: phase == Phase::AV && depth_tile == 0,
                });
            }
        }
    }
}

/// Full schedule of one attention block, fusing QKᵀ and AV per row
/// block as the paper describes ("fuses Q×Kᵀ and A×V in iterations of
/// i"): for each head and each row block, all QKᵀ tiles of the block
/// are followed immediately by its AV tiles.
pub fn attention_schedule(cfg: &ItaConfig, shape: AttentionShape) -> Vec<TileOp> {
    let mut ops = Vec::new();
    let proj = MatmulDims { r: shape.s, k: shape.e, c: shape.p };
    for head in 0..shape.h {
        matmul_ops(cfg, Phase::Q, head, proj, &mut ops);
        matmul_ops(cfg, Phase::K, head, proj, &mut ops);
        matmul_ops(cfg, Phase::V, head, proj, &mut ops);
        // Fused QKᵀ/AV per row block.
        let row_blocks = tiles_ceil(shape.s, cfg.m);
        for rb in 0..row_blocks {
            let mut qk_ops = Vec::new();
            matmul_ops(
                cfg,
                Phase::QK,
                head,
                MatmulDims { r: cfg.m.min(shape.s - rb * cfg.m), k: shape.p, c: shape.s },
                &mut qk_ops,
            );
            for op in &mut qk_ops {
                op.row_tile = rb;
            }
            ops.extend(qk_ops);
            let mut av_ops = Vec::new();
            matmul_ops(
                cfg,
                Phase::AV,
                head,
                MatmulDims { r: cfg.m.min(shape.s - rb * cfg.m), k: shape.s, c: shape.p },
                &mut av_ops,
            );
            for op in &mut av_ops {
                op.row_tile = rb;
            }
            ops.extend(av_ops);
        }
    }
    matmul_ops(
        cfg,
        Phase::OW,
        0,
        MatmulDims { r: shape.s, k: shape.h * shape.p, c: shape.e },
        &mut ops,
    );
    ops
}

/// Total cycles of a schedule (M per tile op, no stalls).
pub fn schedule_cycles(cfg: &ItaConfig, ops: &[TileOp]) -> u64 {
    ops.len() as u64 * cfg.m as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ita::simulator::Simulator;

    #[test]
    fn schedule_totals_match_analytic_model() {
        let cfg = ItaConfig::paper();
        for shape in [
            AttentionShape { s: 64, e: 128, p: 64, h: 2 },
            AttentionShape { s: 128, e: 256, p: 64, h: 4 },
            AttentionShape { s: 65, e: 130, p: 60, h: 3 }, // non-aligned
        ] {
            let ops = attention_schedule(&cfg, shape);
            let analytic = Simulator::new(cfg).simulate_attention(shape);
            assert_eq!(
                schedule_cycles(&cfg, &ops),
                analytic.activity.cycles,
                "shape {shape:?}"
            );
        }
    }

    #[test]
    fn fused_order_alternates_qk_av() {
        let cfg = ItaConfig::paper();
        let shape = AttentionShape { s: 128, e: 128, p: 64, h: 1 };
        let ops = attention_schedule(&cfg, shape);
        // Find first AV op; there must be QK ops before it and QK ops
        // of the *second* row block after it (fusion interleaves).
        let first_av = ops.iter().position(|o| o.phase == Phase::AV).unwrap();
        let later_qk = ops[first_av..].iter().any(|o| o.phase == Phase::QK);
        assert!(later_qk, "QKᵀ of later row blocks must follow the first AV");
        assert!(ops[..first_av].iter().any(|o| o.phase == Phase::QK));
    }

    #[test]
    fn da_marks_final_depth_tiles_only() {
        let cfg = ItaConfig::paper();
        let shape = AttentionShape { s: 128, e: 128, p: 128, h: 1 };
        let ops = attention_schedule(&cfg, shape);
        for op in &ops {
            if op.softmax_da {
                assert_eq!(op.phase, Phase::QK);
                assert!(op.produces_output);
            }
            if op.softmax_en {
                assert_eq!(op.phase, Phase::AV);
            }
        }
        // Every QK column group contributes exactly one DA op per depth
        // completion.
        let da_count = ops.iter().filter(|o| o.softmax_da).count();
        let qk_outputs = ops.iter().filter(|o| o.phase == Phase::QK && o.produces_output).count();
        assert_eq!(da_count, qk_outputs);
    }

    #[test]
    fn head_and_phase_coverage() {
        let cfg = ItaConfig::tiny();
        let shape = AttentionShape { s: 16, e: 16, p: 8, h: 3 };
        let ops = attention_schedule(&cfg, shape);
        for h in 0..3 {
            for ph in [Phase::Q, Phase::K, Phase::V, Phase::QK, Phase::AV] {
                assert!(
                    ops.iter().any(|o| o.head == h && o.phase == ph),
                    "missing head {h} phase {ph:?}"
                );
            }
        }
        assert!(ops.iter().any(|o| o.phase == Phase::OW));
    }
}
